"""RWKV6 ("Finch") blocks — attention-free, data-dependent decay.

Faithfulness notes (DESIGN.md §8): we keep the architecturally-defining v6
feature — the *data-dependent per-channel decay* ``w_t = exp(-exp(w0 +
tanh(x W_a) W_b))`` — and the u-"bonus" first-token path, head-wise state
``S ∈ R^{hs×hs}``, output group-norm and gating. The v6 data-dependent
token-shift (ddlerp) is simplified to static per-channel lerp (v5 style).

Training/prefill runs a chunk-rematerialized scan (sequential within chunk,
``lax.scan`` + ``jax.checkpoint`` across chunks) so activation memory is
O(T/chunk) states. Decode carries {token-shift, state} — O(1)/token, which
is why long_500k is trivial for this arch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dtype, dense_init


def n_rwkv_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_size


def init_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = n_rwkv_heads(cfg)
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    decay_lora = max(32, d // 16)
    return {
        "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
        "mu_v": jnp.full((d,), 0.5, dt), "mu_w": jnp.full((d,), 0.5, dt),
        "mu_g": jnp.full((d,), 0.5, dt),
        "wr": dense_init(ks[0], (d, d), dtype=dt),
        "wk": dense_init(ks[1], (d, d), dtype=dt),
        "wv": dense_init(ks[2], (d, d), dtype=dt),
        "wg": dense_init(ks[3], (d, d), dtype=dt),
        "wo": dense_init(ks[4], (d, d), dtype=dt),
        # data-dependent decay (the v6 feature): w0 + tanh(x A) B
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wa": dense_init(ks[5], (d, decay_lora), dtype=dt),
        "wb": dense_init(ks[6], (decay_lora, d), dtype=dt, scale=0.1),
        "u": (jax.random.normal(ks[7], (d,)) * 0.1).astype(jnp.float32),
        "ln_w": jnp.ones((H, hs), jnp.float32),
        "ln_b": jnp.zeros((H, hs), jnp.float32),
    }


def init_channel_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dt), "mu_r": jnp.full((d,), 0.5, dt),
        "wk": dense_init(ks[0], (d, cfg.d_ff), dtype=dt),
        "wv": dense_init(ks[1], (cfg.d_ff, d), dtype=dt),
        "wr": dense_init(ks[2], (d, d), dtype=dt),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    H, hs = n_rwkv_heads(cfg), cfg.rwkv_head_size
    return {
        "S": jnp.zeros((batch, H, hs, hs), jnp.float32),
        "tm_shift": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_shift": jnp.zeros((batch, cfg.d_model), dtype),
    }


def _token_shift(x, last):
    """x: (b, L, d); last: (b, d) -> shifted (b, L, d), new_last (b, d)."""
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev, x[:, -1]


def _lerp(x, prev, mu):
    return x + (prev - x) * mu


def time_mix(params, x, cfg: ModelConfig, state: dict, *,
             chunk: int = 256, remat: bool = True) -> Tuple[jnp.ndarray, dict]:
    b, L, d = x.shape
    H, hs = n_rwkv_heads(cfg), cfg.rwkv_head_size
    prev, new_shift = _token_shift(x, state["tm_shift"].astype(x.dtype))

    r = _lerp(x, prev, params["mu_r"]) @ params["wr"]
    k = _lerp(x, prev, params["mu_k"]) @ params["wk"]
    v = _lerp(x, prev, params["mu_v"]) @ params["wv"]
    g = jax.nn.silu(_lerp(x, prev, params["mu_g"]) @ params["wg"])
    xw = _lerp(x, prev, params["mu_w"])
    decay_log = -jnp.exp(params["w0"] +
                         (jnp.tanh(xw @ params["wa"]) @ params["wb"]).astype(jnp.float32))
    w = jnp.exp(decay_log)                                  # (b, L, d) in (0,1)

    def heads(t):  # (b, L, d) -> (b, L, H, hs) fp32
        return t.astype(jnp.float32).reshape(b, L, H, hs)

    r, k, v, w = heads(r), heads(k), heads(v), heads(w)
    u = params["u"].reshape(H, hs)

    n_chunks = -(-L // chunk)
    pad = n_chunks * chunk - L
    if pad:
        z = lambda t, c=0.0: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)),
                                     constant_values=c)
        r, k, v, w = z(r), z(k), z(v), z(w, 1.0)
    rc = r.reshape(b, n_chunks, chunk, H, hs).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, n_chunks, chunk, H, hs).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, H, hs).transpose(1, 0, 2, 3, 4)
    wc = w.reshape(b, n_chunks, chunk, H, hs).transpose(1, 0, 2, 3, 4)

    def inner(S, xs):
        rt, kt, vt, wt = xs                                 # (b, H, hs)
        kv = kt[..., :, None] * vt[..., None, :]            # (b, H, hs, hs)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    def chunk_step(S, xs):
        rj, kj, vj, wj = (t.transpose(1, 0, 2, 3) for t in xs)  # (chunk, b, H, hs)
        S, ys = jax.lax.scan(inner, S, (rj, kj, vj, wj))
        return S, ys.transpose(1, 0, 2, 3)                  # (b, chunk, H, hs)

    if remat:
        chunk_step = jax.checkpoint(chunk_step)
    S, ys = jax.lax.scan(chunk_step, state["S"], (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, H, hs)[:, :L]

    # per-head group norm
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y * params["ln_w"] + params["ln_b"]
    y = y.reshape(b, L, d).astype(x.dtype) * g
    out = y @ params["wo"]
    return out, {"S": S, "tm_shift": new_shift.astype(state["tm_shift"].dtype)}


def channel_mix(params, x, cfg: ModelConfig, state: dict) -> Tuple[jnp.ndarray, dict]:
    prev, new_shift = _token_shift(x, state["cm_shift"].astype(x.dtype))
    xk = _lerp(x, prev, params["mu_k"])
    xr = _lerp(x, prev, params["mu_r"])
    r = jax.nn.sigmoid(xr @ params["wr"])
    y = jnp.square(jax.nn.relu(xk @ params["wk"])) @ params["wv"]
    return r * y, {"cm_shift": new_shift.astype(state["cm_shift"].dtype)}


def rwkv_reference_step(params_tm, x_t, S, shift, cfg: ModelConfig):
    """Single-token oracle for tests: x_t (b, d) -> (y, S, shift)."""
    y, st = time_mix(params_tm, x_t[:, None, :], cfg,
                     {"S": S, "tm_shift": shift, "cm_shift": shift},
                     chunk=1, remat=False)
    return y[:, 0], st["S"], st["tm_shift"]
