"""Functional neural-net layers shared by every architecture.

Conventions
-----------
- Params are plain nested dicts of ``jnp.ndarray``; init functions take a PRNG
  key + ``ModelConfig`` and return the dict. No module framework.
- Attention tensors use grouped-query layout:
  q: ``(batch, Lq, n_kv, q_per_kv, head_dim)``; k/v: ``(batch, Lk, n_kv, head_dim)``.
- Attention logits/softmax are computed in fp32 regardless of param dtype.
- Visibility is supplied as ``bias_fn(q_pos, kv_pos, kv_valid) -> (Lq, Lk)``
  additive bias so chunked ("flash-style") attention never materializes L².
"""
from __future__ import annotations

import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32, scale=1.0):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"w": jnp.ones((d,), _dtype(cfg)), "b": jnp.zeros((d,), _dtype(cfg))}
    return {"w": jnp.ones((d,), _dtype(cfg))}


def apply_norm(params, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * params["w"].astype(jnp.float32) + params["b"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * params["w"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """x: (..., L, ..., head_dim); positions: (L,) or (b, L)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    pos = jnp.asarray(positions, jnp.float32)
    ang = pos[..., None] * freqs  # (..., L, half)
    # broadcast ang to x's rank: x is (b, L, heads..., hd)
    while ang.ndim < x.ndim - 1:
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions, d_model: int):
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(10_000.0) / max(half - 1, 1)))
    ang = jnp.asarray(positions, jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p = {
        "wq": dense_init(ks[0], (d, nq * hd), dtype=dt),
        "wk": dense_init(ks[1], (d, nkv * hd), dtype=dt),
        "wv": dense_init(ks[2], (d, nkv * hd), dtype=dt),
        "wo": dense_init(ks[3], (nq * hd, d), dtype=dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((nq * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
    return p


def project_q(params, x, cfg: ModelConfig):
    b, L, _ = x.shape
    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"]
    return q.reshape(b, L, cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim)


def project_kv(params, x, cfg: ModelConfig):
    b, L, _ = x.shape
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    k = k.reshape(b, L, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, L, cfg.n_kv_heads, cfg.head_dim)
    return k, v


def out_proj(params, attn_out, cfg: ModelConfig):
    b, L = attn_out.shape[:2]
    return attn_out.reshape(b, L, cfg.n_heads * cfg.head_dim) @ params["wo"]


def attn_scale(cfg: ModelConfig) -> float:
    if cfg.query_pre_attn_scalar is not None:
        return 1.0 / math.sqrt(cfg.query_pre_attn_scalar)
    return 1.0 / math.sqrt(cfg.head_dim)


BiasFn = Callable[..., jnp.ndarray]


def _dense_attention(q, k, v, *, q_pos, kv_pos, kv_valid, bias_fn: BiasFn,
                     scale: float, cap: Optional[float]):
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    bias = bias_fn(q_pos, kv_pos, kv_valid)  # (Lq, Lk)
    scores = scores + bias[None, None, None]
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v)


def _chunked_attention(q, k, v, *, q_pos, kv_pos, kv_valid, bias_fn: BiasFn,
                       scale: float, cap: Optional[float], chunk: int,
                       q_chunk: int = 1024):
    """Online-softmax ("flash") attention: ``lax.map`` over query chunks ×
    ``lax.scan`` over KV chunks. Live score memory is O(q_chunk × chunk)
    instead of O(Lq × Lk)."""
    b, Lq, Kv, G, hd = q.shape
    if Lq > q_chunk and Lq % q_chunk == 0:
        n_q = Lq // q_chunk

        def one(j):
            qj = jax.lax.dynamic_slice_in_dim(q, j * q_chunk, q_chunk, 1)
            pj = jax.lax.dynamic_slice_in_dim(jnp.asarray(q_pos),
                                              j * q_chunk, q_chunk, 0)
            return _chunked_attention(qj, k, v, q_pos=pj, kv_pos=kv_pos,
                                      kv_valid=kv_valid, bias_fn=bias_fn,
                                      scale=scale, cap=cap, chunk=chunk,
                                      q_chunk=q_chunk)

        out = jax.lax.map(one, jnp.arange(n_q))  # (n_q, b, q_chunk, ...)
        return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, Lq, Kv, G, hd)
    Lk = k.shape[1]
    n_chunks = -(-Lk // chunk)
    pad = n_chunks * chunk - Lk
    if kv_valid is None:
        kv_valid = jnp.ones((Lk,), bool)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(jnp.asarray(kv_pos), (0, pad), constant_values=-1)
        kv_valid = jnp.pad(kv_valid, (0, pad), constant_values=False)

    # NOTE: chunks are taken with dynamic_slice on the ORIGINAL layout —
    # an earlier reshape+transpose version forced SPMD "involuntary full
    # rematerialization" (replicating k/v per period); slicing along the
    # sequence dim preserves batch/head shardings (EXPERIMENTS.md §Perf).
    qf = q.astype(jnp.float32) * scale
    m0 = jnp.full((b, Kv, G, Lq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, Kv, G, Lq), jnp.float32)
    acc0 = jnp.zeros((b, Lq, Kv, G, hd), jnp.float32)

    def step(carry, j):
        m, l, acc = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, 1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, 1)
        posj = jax.lax.dynamic_slice_in_dim(kv_pos, j * chunk, chunk, 0)
        valj = jax.lax.dynamic_slice_in_dim(kv_valid, j * chunk, chunk, 0)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kj.astype(jnp.float32))
        s = softcap(s, cap)
        s = s + bias_fn(q_pos, posj, valj)[None, None, None]
        mj = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows: exp(-inf - -inf) -> use finite floor
        mj_safe = jnp.where(jnp.isfinite(mj), mj, 0.0)
        p = jnp.exp(s - mj_safe[..., None])
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - mj_safe), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None]
        acc = acc + jnp.einsum("bkgqs,bskh->bqkgh", p, vj.astype(jnp.float32))
        return (mj, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                  jnp.arange(n_chunks))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.astype(v.dtype)


def attention_core(q, k, v, *, q_pos, kv_pos, kv_valid=None, bias_fn: BiasFn,
                   scale: float, cap: Optional[float] = None,
                   impl: str = "auto", chunk: int = 2048):
    """Grouped-query attention with pluggable visibility.

    q: (b, Lq, Kv, G, hd); k/v: (b, Lk, Kv, hd) -> (b, Lq, Kv, G, hd)
    """
    Lk = k.shape[1]
    if impl == "auto":
        impl = "chunked" if Lk >= 4096 else "dense"
    if impl == "dense":
        if kv_valid is None:
            kv_valid = jnp.ones((Lk,), bool)
        return _dense_attention(q.astype(jnp.float32), k, v, q_pos=q_pos,
                                kv_pos=kv_pos, kv_valid=kv_valid,
                                bias_fn=bias_fn, scale=scale, cap=cap)
    if impl == "chunked":
        return _chunked_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                  kv_valid=kv_valid, bias_fn=bias_fn,
                                  scale=scale, cap=cap, chunk=chunk)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    d_ff = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.activation == "gelu_plain":
        return {"wi": dense_init(ks[0], (d, d_ff), dtype=dt),
                "wo": dense_init(ks[1], (d_ff, d), dtype=dt)}
    return {"wi_gate": dense_init(ks[0], (d, d_ff), dtype=dt),
            "wi_up": dense_init(ks[1], (d, d_ff), dtype=dt),
            "wo": dense_init(ks[2], (d_ff, d), dtype=dt)}


def _act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind in ("gelu", "gelu_plain"):
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {kind!r}")


def apply_mlp(params, x, cfg: ModelConfig):
    if "wi" in params:  # non-gated (whisper)
        return _act(x @ params["wi"], cfg.activation) @ params["wo"]
    g = _act(x @ params["wi_gate"], cfg.activation)
    return (g * (x @ params["wi_up"])) @ params["wo"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 2)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype=dt)
    return p


def embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["tok"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed_w(params, cfg: ModelConfig):
    """The (d, V) unembedding matrix ``lm_head`` applies (tied: transposed
    view of the token embedding). Consumed directly by the fused
    unembed+select decode kernel (``repro.kernels.select``)."""
    return params["tok"].T if cfg.tie_embeddings else params["head"]


def lm_head(params, x, cfg: ModelConfig):
    logits = jnp.einsum("bld,dv->blv", x, unembed_w(params, cfg),
                        preferred_element_type=jnp.float32)
    return softcap(logits, cfg.final_logit_softcap)
