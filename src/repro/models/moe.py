"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Design (TPU-native, see DESIGN.md §4): instead of the GShard (T, E, C)
dispatch einsum — whose one-hot tensor is quadratic in tokens×experts — we
compute each token's position-in-expert by a cumulative sum over the one-hot
routing matrix (T, E), then scatter tokens into an expert-major buffer
``(E, C, d)``, run a single batched expert einsum ``(E,C,d)x(E,d,f)``, and
gather back. Over-capacity tokens are dropped (residual passthrough), the
standard Switch/GShard behavior. With experts sharded over the ``model`` mesh
axis the scatter/gather lower to all-to-all-style collectives.

A shared expert (Kimi/DeepSeek style) is applied densely to every token.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _act, _dtype, dense_init


def init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(f)
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "wi_gate": (jax.random.normal(ks[1], (E, d, f)) * std_in).astype(dt),
        "wi_up": (jax.random.normal(ks[2], (E, d, f)) * std_in).astype(dt),
        "wo": (jax.random.normal(ks[3], (E, f, d)) * std_out).astype(dt),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi_gate": dense_init(ks2[0], (d, fs), dtype=dt),
            "wi_up": dense_init(ks2[1], (d, fs), dtype=dt),
            "wo": dense_init(ks2[2], (fs, d), dtype=dt),
        }
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    E, k = cfg.n_experts, cfg.experts_per_token
    c = int(math.ceil(n_tokens * k * cfg.capacity_factor / E))
    return max(c, 4)


def apply_moe(params, x, cfg: ModelConfig,
              dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, L, d) -> (out, aux_loss).

    ``dropless=True`` sizes expert buffers so no token can be dropped
    (C = T, worst case all tokens routed to one expert) — used for decode
    steps where L is a single block, making cached inference *exact*.
    Capacity-based dropping remains the training configuration; the
    prefill-vs-decode capacity mismatch is inherent to capacity routing and
    documented in DESIGN.md.
    """
    b, L, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = b * L
    if dropless:
        # bounded-worst-case decode capacity: 8x the balanced load (drops
        # only under pathological imbalance) instead of C=T, which sized
        # expert buffers E*T and made decode compute/collectives ~E/8x
        # redundant (EXPERIMENTS.md §Perf H2). Small T keeps exact C=T.
        import math as _math
        C = min(T, max(4, _math.ceil(T * K * 8.0 / E)))
    else:
        C = capacity(T, cfg)
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)       # (T, K)
    # normalize the selected gates (top-k renorm, deepseek/mixtral style)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch): E * sum_e f_e * P_e
    one_hot_all = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32).sum(1)  # (T, E)
    frac_tokens = one_hot_all.mean(0)
    frac_probs = probs.mean(0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    out = jnp.zeros((T, d), jnp.float32)
    for j in range(K):
        eid = expert_ids[:, j]                       # (T,)
        gj = gate_vals[:, j]
        onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)          # (T, E)
        pos = jnp.cumsum(onehot, axis=0) * onehot                 # rank within expert
        pos_in_e = jnp.sum(pos, axis=-1) - 1                      # (T,)
        keep = pos_in_e < C
        flat_idx = jnp.where(keep, eid * C + pos_in_e, E * C)     # E*C = drop slot
        # scatter tokens -> (E*C+1, d), last row is the drop bucket
        buf = jnp.zeros((E * C + 1, d), xt.dtype).at[flat_idx].set(xt)
        buf = buf[: E * C].reshape(E, C, d)
        g = _act(jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"]), cfg.activation)
        u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"])
        y = jnp.einsum("ecf,efd->ecd", g * u, params["wo"])       # (E, C, d)
        y = y.reshape(E * C, d)
        gathered = jnp.take(y, jnp.minimum(flat_idx, E * C - 1), axis=0)
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        out = out + gathered.astype(jnp.float32) * gj[:, None]

    if "shared" in params:
        sp = params["shared"]
        g = _act(xt @ sp["wi_gate"], cfg.activation)
        out = out + ((g * (xt @ sp["wi_up"])) @ sp["wo"]).astype(jnp.float32)

    return out.reshape(b, L, d).astype(x.dtype), aux


def apply_moe_dense_fallback(params, x, cfg: ModelConfig):
    """Reference path: run all experts on all tokens (tests only)."""
    b, L, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    g = _act(jnp.einsum("td,edf->tef", xt, params["wi_gate"]), cfg.activation)
    u = jnp.einsum("td,edf->tef", xt, params["wi_up"])
    y = jnp.einsum("tef,efd->ted", g * u, params["wo"])   # (T, E, d)
    w = jnp.zeros((xt.shape[0], cfg.n_experts), jnp.float32)
    w = jax.vmap(lambda wr, ids, gs: wr.at[ids].add(gs))(w, expert_ids, gate_vals)
    out = jnp.einsum("te,ted->td", w, y.astype(jnp.float32))
    if "shared" in params:
        sp = params["shared"]
        gg = _act(xt @ sp["wi_gate"], cfg.activation)
        out = out + ((gg * (xt @ sp["wi_up"])) @ sp["wo"]).astype(jnp.float32)
    return out.reshape(b, L, d).astype(x.dtype)
