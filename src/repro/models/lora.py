"""LoRA adapters (paper App. A.2: rank 32/64 on attention + MLP projections).

We store adapters as a sparse mirror of the param tree: a dict keyed by the
"/"-joined param path of each targeted 2-D matrix, each entry {"a": (in, r),
"b": (r, out)}. ``merge`` materializes W + (alpha/r)·A·B for the forward —
at framework scale one would fuse the factored matmul instead; the merged
form keeps every downstream code path (sharding, caching, kernels)
unchanged and is exactly equivalent.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

DEFAULT_TARGETS = ("wq", "wk", "wv", "wo", "wi_gate", "wi_up", "wi")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def init_lora(key, params, *, rank: int,
              targets: Sequence[str] = DEFAULT_TARGETS) -> Dict[str, dict]:
    lora = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        name = _path_str(path)
        tail = name.split("/")[-1]
        if tail in targets and leaf.ndim >= 2:
            key, k1 = jax.random.split(key)
            in_dim, out_dim = leaf.shape[-2], leaf.shape[-1]
            lead = leaf.shape[:-2]
            a = (jax.random.normal(k1, (*lead, in_dim, rank)) /
                 jnp.sqrt(in_dim)).astype(leaf.dtype)
            b = jnp.zeros((*lead, rank, out_dim), leaf.dtype)
            lora[name] = {"a": a, "b": b}
    return lora


def merge(params, lora: Dict[str, dict], alpha: float, rank: int):
    """Return params with W <- W + (alpha/rank) A@B on targeted leaves."""
    scale = alpha / rank

    def fix(path, leaf):
        name = _path_str(path)
        if name in lora:
            ab = jnp.einsum("...ir,...ro->...io", lora[name]["a"],
                            lora[name]["b"])
            return leaf + (scale * ab).astype(leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, params)


def param_count(lora) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(lora))
