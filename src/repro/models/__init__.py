from repro.models.transformer import (  # noqa: F401
    ModelOutput,
    forward,
    init_model,
    unembed_matrix,
)
