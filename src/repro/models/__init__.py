from repro.models.transformer import ModelOutput, forward, init_model  # noqa: F401
