"""The composable model stack.

One code path serves all ten assigned architectures. A model is a repeated
*period* of heterogeneous layer slots (``cfg.layer_period``), e.g. gemma2 is
``((ATTN_LOCAL, MLP), (ATTN, MLP))`` × 23 and jamba is an 8-slot
Mamba/attention/MoE interleave × 4. Parameters for each slot are stacked
over periods and the stack is executed with ``lax.scan`` so the HLO (and
single-core compile time) stays O(period), not O(n_layers) — essential for
the 61–80 layer archs.

The same ``forward`` implements:
- full-sequence forward (training / prefill), any mask mode
  (bidirectional teacher / block-causal student / causal AR);
- cached decode: a B-token active-block refinement step (or 1-token AR step)
  against KV/SSM caches, the paper's §4.3 inference unit.

Per-slot "emissions" (new KV, SSM states) are returned stacked so the cache
layer (`repro.core.cache`) can commit them at block boundaries — CDLM's
exact block-wise KV caching.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN,
    ATTN_LOCAL,
    MAMBA,
    MLP,
    MOE,
    RWKV,
    RWKV_CM,
    ModelConfig,
)
from repro.core import masks
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MO
from repro.models import rwkv6 as R


class ModelOutput(NamedTuple):
    logits: Optional[jnp.ndarray]  # (b, Lq, vocab) fp32; None when the
    #                                caller asked for return_logits=False
    #                                (fused-select decode reads hidden)
    hidden: jnp.ndarray            # (b, Lq, d) last hidden (post final norm)
    emissions: Any                 # per-slot stacked cache/state emissions
    aux_loss: jnp.ndarray          # MoE load-balance aux (scalar fp32)


def unembed_matrix(params, cfg: ModelConfig):
    """The (d, V) matrix ``lm_head`` would multiply by — handed to the
    fused unembed+select kernel so decode never materializes logits."""
    return L.unembed_w(params["embed"], cfg)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_slot(key, cfg: ModelConfig, mixer: str, ffn: str, *, cross: bool):
    ks = jax.random.split(key, 6)
    slot = {"norm1": L.init_norm(cfg), "norm2": L.init_norm(cfg)}
    if mixer in (ATTN, ATTN_LOCAL):
        slot["attn"] = L.init_attention(ks[0], cfg)
    elif mixer == MAMBA:
        slot["mamba"] = M.init_mamba(ks[0], cfg)
    elif mixer == RWKV:
        slot["rwkv_tm"] = R.init_time_mix(ks[0], cfg)
    else:
        raise ValueError(mixer)
    if cross:
        slot["cross"] = L.init_attention(ks[1], cfg, cross=True)
        slot["norm_cross"] = L.init_norm(cfg)
    if ffn == MLP:
        slot["mlp"] = L.init_mlp(ks[2], cfg)
    elif ffn == MOE:
        slot["moe"] = MO.init_moe(ks[2], cfg)
    elif ffn == RWKV_CM:
        slot["rwkv_cm"] = R.init_channel_mix(ks[2], cfg)
    else:
        raise ValueError(ffn)
    return slot


def _stack_slot_init(key, cfg: ModelConfig, mixer: str, ffn: str, n: int,
                     *, cross: bool):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_slot(k, cfg, mixer, ffn, cross=cross))(keys)


def init_model(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2 + len(cfg.layer_period) + cfg.is_encoder_decoder)
    params = {"embed": L.init_embed(ks[0], cfg), "final_norm": L.init_norm(cfg)}
    slots = []
    for i, (mixer, ffn) in enumerate(cfg.layer_period):
        slots.append(_stack_slot_init(ks[2 + i], cfg, mixer, ffn, cfg.n_periods,
                                      cross=cfg.is_encoder_decoder))
    params["slots"] = tuple(slots)
    if cfg.is_encoder_decoder:
        ek = jax.random.split(ks[1], 2)
        params["encoder"] = {
            "slots": (_stack_slot_init(ek[0], cfg, ATTN, MLP,
                                       cfg.n_encoder_layers, cross=False),),
            "final_norm": L.init_norm(cfg),
        }
    return params


# ---------------------------------------------------------------------------
# Slot application
# ---------------------------------------------------------------------------
def _gather_pages(pool, table):
    """Dense per-lane view of a KV page pool.

    pool: (n_pages, page, kv, hd); table: (b, n_tables) int32 page ids
    (-1 = unallocated). Returns (b, n_tables*page, kv, hd). Unallocated
    entries gather an arbitrary page — those positions are always >=
    ``cache_len`` and masked out of the attention bias."""
    b, n_t = table.shape
    g = pool[jnp.clip(table, 0, pool.shape[0] - 1)]
    return g.reshape(b, n_t * pool.shape[1], *pool.shape[2:])


def _self_attention_slot(slot, x, *, cfg: ModelConfig, mixer: str, ctx):
    """Returns (y, emission)."""
    h = L.apply_norm(slot["norm1"], x, cfg)
    q = L.project_q(slot["attn"], h, cfg)
    k, v = L.project_kv(slot["attn"], h, cfg)
    if cfg.pos_embed == "rope":
        q = L.rope(q, ctx["q_pos"], cfg.rope_theta)
        k = L.rope(k, ctx["q_pos"], cfg.rope_theta)

    window = None
    if mixer == ATTN_LOCAL:
        window = cfg.sliding_window
    elif ctx["use_long_window"] and cfg.long_context_window:
        window = cfg.long_context_window

    emission = {"k": k, "v": v}
    cache = ctx["cache_slot"]
    pages = ctx.get("pages")
    scale = L.attn_scale(cfg)
    cap = cfg.attn_logit_softcap

    if (cache is not None and "k" in cache and pages is not None
            and ctx.get("paged_decode_attention_fn") is not None
            and ctx.get("cache_valid") is None):
        # paged flash-decode: the kernel walks the page table directly, no
        # dense gather is materialized
        out = ctx["paged_decode_attention_fn"](
            q, cache["k"], cache["v"], k, v, pages, ctx["cache_len"],
            scale=scale, softcap=cap, window=window)
    elif (cache is not None and "k" in cache and pages is None
            and ctx.get("decode_attention_fn") is not None
            and ctx.get("cache_valid") is None):
        # pluggable decode path: Pallas flash-decode kernel or the
        # sequence-parallel shard_map implementation (repro.parallel)
        out = ctx["decode_attention_fn"](
            q, cache["k"], cache["v"], k, v, ctx["cache_len"], scale=scale,
            softcap=cap, window=window)
    else:
        if cache is not None and "k" in cache:
            if pages is not None:
                # paged layout: cache["k"]/["v"] are page pools
                # (n_pages, page, kv, hd); gather the lanes' pages into the
                # dense view, then the math below is bit-identical to the
                # dense layout (invalid positions are masked the same way,
                # so residual page contents never reach the output).
                ck, cv = _gather_pages(cache["k"], pages), \
                    _gather_pages(cache["v"], pages)
            else:
                ck, cv = cache["k"], cache["v"]
            S = ck.shape[1]
            k_all = jnp.concatenate([ck, k.astype(ck.dtype)], axis=1)
            v_all = jnp.concatenate([cv, v.astype(cv.dtype)], axis=1)
            kv_pos = jnp.concatenate([jnp.arange(S), jnp.asarray(ctx["q_pos"])])
            if ctx.get("cache_valid") is not None:
                cache_ok = ctx["cache_valid"]
            else:
                cache_ok = jnp.arange(S) < ctx["cache_len"]
            kv_valid = jnp.concatenate([cache_ok,
                                        jnp.ones((k.shape[1],), bool)])
        else:
            k_all, v_all = k, v
            kv_pos = ctx["q_pos"]
            kv_valid = None

        bias_fn = masks.make_bias_fn(mode=ctx["mode"],
                                     prompt_len=ctx["prompt_len"],
                                     block_size=ctx["block_size"],
                                     window=window)

        def bias_with_valid(q_pos, k_pos, valid):
            b = bias_fn(q_pos, k_pos)
            if valid is not None:
                b = jnp.where(valid[None, :], b, masks.NEG_INF)
            return b

        out = ctx["attention_fn"](
            q, k_all, v_all, q_pos=ctx["q_pos"], kv_pos=kv_pos,
            kv_valid=kv_valid, bias_fn=bias_with_valid, scale=scale,
            cap=cap, impl=ctx["attn_impl"])
    y = L.out_proj(slot["attn"], out, cfg)
    return x + y, emission


def _cross_attention_slot(slot, x, *, cfg: ModelConfig, ctx):
    h = L.apply_norm(slot["norm_cross"], x, cfg)
    q = L.project_q(slot["cross"], h, cfg)
    cache = ctx["cache_slot"]
    if cache is not None and "ck" in cache:
        ck, cv = cache["ck"], cache["cv"]
        emission = {}
    else:
        ck, cv = L.project_kv(slot["cross"], ctx["encoder_out"], cfg)
        emission = {"ck": ck, "cv": cv}
    enc_len = ck.shape[1]

    def cross_bias(qp, kp, valid):
        return jnp.zeros((jnp.asarray(qp).shape[0], jnp.asarray(kp).shape[0]),
                         jnp.float32)

    out = L.attention_core(
        q, ck, cv, q_pos=ctx["q_pos"], kv_pos=jnp.arange(enc_len), kv_valid=None,
        bias_fn=cross_bias, scale=L.attn_scale(cfg), cap=None, impl="dense")
    return x + L.out_proj(slot["cross"], out, cfg), emission


def _apply_slot(slot, x, *, cfg: ModelConfig, mixer: str, ffn: str, ctx):
    emission = {}
    aux = jnp.zeros((), jnp.float32)
    cache = ctx["cache_slot"]

    # --- mixer sublayer ---
    if mixer in (ATTN, ATTN_LOCAL):
        x, em = _self_attention_slot(slot, x, cfg=cfg, mixer=mixer, ctx=ctx)
        emission.update(em)
    elif mixer == MAMBA:
        h = L.apply_norm(slot["norm1"], x, cfg)
        state = None
        if cache is not None and "ssm" in cache:
            state = {"conv": cache["conv"], "ssm": cache["ssm"]}
        y, new_state = M.mamba_forward(slot["mamba"], h, cfg, state=state,
                                       remat=False)
        x = x + y
        emission.update(new_state)
    elif mixer == RWKV:
        h = L.apply_norm(slot["norm1"], x, cfg)
        if cache is not None and "S" in cache:
            state = {"S": cache["S"], "tm_shift": cache["tm_shift"],
                     "cm_shift": cache["cm_shift"]}
        else:
            state = R.init_rwkv_state(cfg, x.shape[0], dtype=x.dtype)
        y, new_tm = R.time_mix(slot["rwkv_tm"], h, cfg, state, remat=False)
        x = x + y
        emission.update(new_tm)
        ctx = dict(ctx, rwkv_state=state)   # channel mix needs cm_shift
    else:
        raise ValueError(mixer)

    # --- cross attention (enc-dec) ---
    if "cross" in slot and (ctx.get("encoder_out") is not None
                            or (cache is not None and "ck" in cache)):
        x, em = _cross_attention_slot(slot, x, cfg=cfg, ctx=ctx)
        emission.update(em)

    # --- ffn sublayer ---
    h = L.apply_norm(slot["norm2"], x, cfg)
    if ffn == MLP:
        x = x + L.apply_mlp(slot["mlp"], h, cfg)
    elif ffn == MOE:
        y, a = MO.apply_moe(slot["moe"], h, cfg,
                            dropless=ctx.get("moe_dropless", False))
        x = x + y
        aux = aux + a
    elif ffn == RWKV_CM:
        y, new_cm = R.channel_mix(slot["rwkv_cm"], h, cfg, ctx["rwkv_state"])
        x = x + y
        emission.update(new_cm)
    return x, emission, aux


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------
def _run_stack(slots_params, x, *, cfg: ModelConfig, slot_kinds, ctx,
               cache=None, remat: bool = False, unroll: bool = False):
    """Scan the period stack. ``slots_params``/``cache``: tuple over slots,
    leaves stacked over periods. Returns (x, emissions, aux).

    ``unroll=True`` python-loops the periods instead of ``lax.scan`` — used
    by the roofline dry-run variants because XLA's cost_analysis counts a
    scan body once regardless of trip count (verified empirically)."""

    def period_body(carry, xs):
        x, aux = carry
        slot_params_t, cache_t = xs
        emissions_t = []
        for i, (mixer, ffn) in enumerate(slot_kinds):
            c = dict(ctx, cache_slot=None if cache_t is None else cache_t[i])
            x, em, a = _apply_slot(slot_params_t[i], x, cfg=cfg, mixer=mixer,
                                   ffn=ffn, ctx=c)
            emissions_t.append(em)
            aux = aux + a
        return (x, aux), tuple(emissions_t)

    body = jax.checkpoint(period_body) if remat else period_body
    init = (x, jnp.zeros((), jnp.float32))
    if unroll:
        n = jax.tree_util.tree_leaves(slots_params)[0].shape[0]
        carry = init
        ems = []
        for i in range(n):
            sp_i = jax.tree_util.tree_map(lambda a: a[i], slots_params)
            c_i = (None if cache is None
                   else jax.tree_util.tree_map(lambda a: a[i], cache))
            carry, em = body(carry, (sp_i, c_i))
            ems.append(em)
        (x, aux) = carry
        emissions = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ems)
        return x, emissions, aux
    if cache is None:
        (x, aux), emissions = jax.lax.scan(
            lambda c, sp: body(c, (sp, None)), init, slots_params)
    else:
        (x, aux), emissions = jax.lax.scan(body, init, (slots_params, cache))
    return x, emissions, aux


def forward(
    params,
    tokens: Optional[jnp.ndarray] = None,
    *,
    cfg: ModelConfig,
    mode: str = masks.BIDIRECTIONAL,
    prompt_len: int = 0,
    block_size: int = 1,
    positions: Optional[jnp.ndarray] = None,
    prefix_embeds: Optional[jnp.ndarray] = None,
    encoder_embeds: Optional[jnp.ndarray] = None,
    inputs_embeds: Optional[jnp.ndarray] = None,
    cache=None,
    cache_len=None,
    cache_valid=None,
    pages=None,
    use_long_window: bool = False,
    attn_impl: str = "auto",
    attention_fn=None,
    decode_attention_fn=None,
    paged_decode_attention_fn=None,
    remat: bool = False,
    unroll_layers: bool = False,
    logits_slice: Optional[Tuple[int, int]] = None,
    return_logits: bool = True,
    moe_dropless: Optional[bool] = None,
) -> ModelOutput:
    """Run the model.

    tokens: (b, L) int32 (or ``inputs_embeds``). ``prefix_embeds``
    (b, P, d): stub-frontend embeddings (VLM patches) prepended to the token
    embeddings — they are part of the prompt for masking purposes.
    ``encoder_embeds`` (b, enc_len, d): whisper frame embeddings (stub conv
    frontend) consumed by the encoder. ``cache``/``cache_len``: decode.
    ``pages`` (b, n_tables) int32: page tables for a block-paged cache —
    when given, attention K/V cache leaves are interpreted as page pools
    (``repro.core.cache.PagedCache.slots``) instead of per-lane buffers.
    """
    if attention_fn is None:
        attention_fn = L.attention_core
    # accept a repro.core.cache.PagedCache directly (duck-typed to avoid a
    # models <-> core import cycle): unpack pool slots + page tables
    if cache is not None and hasattr(cache, "page_table") \
            and hasattr(cache, "slots"):
        pages = cache.page_table if pages is None else pages
        cache = cache.slots

    if inputs_embeds is not None:
        x = inputs_embeds
        b, Lt = x.shape[:2]
    else:
        b, Lt = tokens.shape
        x = L.embed_tokens(params["embed"], tokens, cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    Lq = x.shape[1]

    if positions is None:
        base = cache_len if cache_len is not None else 0
        positions = base + jnp.arange(Lq)
    if cfg.pos_embed == "sinusoidal":
        x = x + L.sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)

    # encoder (whisper): bidirectional over stub frame embeddings
    encoder_out = None
    if cfg.is_encoder_decoder and encoder_embeds is not None:
        enc = encoder_embeds
        enc_pos = jnp.arange(enc.shape[1])
        if cfg.pos_embed == "sinusoidal":
            enc = enc + L.sinusoidal_embedding(enc_pos, cfg.d_model).astype(enc.dtype)
        enc_ctx = dict(
            mode=masks.BIDIRECTIONAL, prompt_len=0, block_size=1,
            q_pos=enc_pos, cache_len=None, cache_slot=None,
            use_long_window=False, attn_impl=attn_impl,
            attention_fn=attention_fn, encoder_out=None, rwkv_state=None)
        enc_x, _, _ = _run_stack(params["encoder"]["slots"], enc, cfg=cfg,
                                 slot_kinds=((ATTN, MLP),), ctx=enc_ctx,
                                 cache=None, remat=remat,
                                 unroll=unroll_layers)
        encoder_out = L.apply_norm(params["encoder"]["final_norm"], enc_x, cfg)

    ctx = dict(
        mode=mode, prompt_len=prompt_len, block_size=block_size,
        q_pos=positions, cache_len=cache_len, cache_valid=cache_valid,
        pages=pages, cache_slot=None, use_long_window=use_long_window,
        attn_impl=attn_impl, attention_fn=attention_fn,
        decode_attention_fn=decode_attention_fn,
        paged_decode_attention_fn=paged_decode_attention_fn,
        encoder_out=encoder_out, rwkv_state=None,
        # decode steps (cache present) default to dropless MoE so cached
        # inference is exact; training/prefill keep capacity dropping.
        moe_dropless=(cache is not None) if moe_dropless is None else moe_dropless)

    x, emissions, aux = _run_stack(params["slots"], x, cfg=cfg,
                                   slot_kinds=cfg.layer_period, ctx=ctx,
                                   cache=cache, remat=remat,
                                   unroll=unroll_layers)

    hidden = L.apply_norm(params["final_norm"], x, cfg)
    # return_logits=False: the fused-select decode mode — the caller
    # consumes hidden (+ unembed_matrix) through the streaming selection
    # kernel, so the (b, Lq, V) logits tensor is never built.
    if not return_logits:
        return ModelOutput(logits=None, hidden=hidden, emissions=emissions,
                           aux_loss=aux)
    # perf: the CDLM losses only consume generation-span logits — slicing
    # before the lm_head avoids materializing (b, L, V) over the prompt half
    # (EXPERIMENTS.md §Perf iteration 1).
    head_in = hidden if logits_slice is None else \
        hidden[:, logits_slice[0]:logits_slice[1]]
    logits = L.lm_head(params["embed"], head_in, cfg)
    return ModelOutput(logits=logits, hidden=hidden, emissions=emissions,
                       aux_loss=aux)
