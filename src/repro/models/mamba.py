"""Mamba selective-SSM block (for the Jamba hybrid).

Training/prefill uses a chunked associative scan: the diagonal recurrence
``h_t = a_t * h_{t-1} + b_t`` is evaluated with ``lax.associative_scan``
inside fixed-size chunks wrapped in ``jax.checkpoint`` (rematerialized in the
backward pass), with a sequential ``lax.scan`` carrying the state across
chunks. Decode uses a single-step state update (conv window + SSM state),
which is the Jamba "cache" — O(1) per token.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dtype, dense_init


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    e = cfg.mamba_expand * d
    N = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    r = dt_rank(cfg)
    dt = _dtype(cfg)
    ks = jax.random.split(key, 7)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (e, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * e), dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (dc, e)) / math.sqrt(dc)).astype(dt),
        "conv_b": jnp.zeros((e,), dt),
        "x_proj": dense_init(ks[2], (e, r + 2 * N), dtype=dt),
        "dt_proj_w": dense_init(ks[3], (r, e), dtype=dt),
        "dt_proj_b": jnp.full((e,), math.log(math.expm1(0.01)), dt),  # softplus^-1(0.01)
        "A_log": jnp.log(A),                     # (e, N) fp32
        "D": jnp.ones((e,), jnp.float32),
        "out_proj": dense_init(ks[4], (e, d), dtype=dt),
    }


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    e = cfg.mamba_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, e), dtype),
        "ssm": jnp.zeros((batch, e, cfg.mamba_d_state), jnp.float32),
    }


def _ssm_coeffs(params, xz, cfg: ModelConfig):
    """From post-conv activations u: (b, L, e) produce a_t, b_t, C, dt."""
    N = cfg.mamba_d_state
    r = dt_rank(cfg)
    u = xz
    proj = u @ params["x_proj"]                        # (b, L, r + 2N)
    dt_in, B, C = jnp.split(proj, [r, r + N], axis=-1)
    delta = jax.nn.softplus(dt_in @ params["dt_proj_w"] + params["dt_proj_b"])
    delta = delta.astype(jnp.float32)                  # (b, L, e)
    A = -jnp.exp(params["A_log"])                      # (e, N)
    a = jnp.exp(delta[..., None] * A[None, None])      # (b, L, e, N)
    # bt: (b, L, e, N) = (delta*u) (b,L,e) outer B (b,L,N)
    bt = (delta * u.astype(jnp.float32))[..., None] * B.astype(jnp.float32)[:, :, None, :]
    return a, bt, C.astype(jnp.float32), delta


def _chunk_scan(a, b, h0):
    """Associative scan of h_t = a_t h_{t-1} + b_t within a chunk.

    a, b: (bsz, L, e, N); h0: (bsz, e, N) -> (h_all (bsz, L, e, N), h_last)."""
    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    # fold h0 into the first step
    b = b.at[:, 0].add(a[:, 0] * h0)
    a_cum, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def mamba_forward(params, x, cfg: ModelConfig, *,
                  state: Optional[dict] = None, chunk: int = 256,
                  remat: bool = True) -> Tuple[jnp.ndarray, dict]:
    """x: (b, L, d) -> (y, new_state). Causal; state carries (conv, ssm)."""
    bsz, L, d = x.shape
    e = cfg.mamba_expand * d
    dc = cfg.mamba_d_conv
    if state is None:
        state = init_mamba_state(cfg, bsz, dtype=x.dtype)

    xz = x @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                   # (b, L, e) each

    # causal depthwise conv with carried window
    conv_in = jnp.concatenate([state["conv"].astype(u.dtype), u], axis=1)
    windows = [conv_in[:, i:i + L] for i in range(dc)]  # each (b, L, e)
    u_conv = sum(w * params["conv_w"][i] for i, w in enumerate(windows)) + params["conv_b"]
    u_conv = jax.nn.silu(u_conv)
    new_conv = conv_in[:, -(dc - 1):] if dc > 1 else state["conv"]

    a, bt, C, _ = _ssm_coeffs(params, u_conv, cfg)     # (b, L, e, N)...

    n_chunks = -(-L // chunk)
    pad = n_chunks * chunk - L
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bt = jnp.pad(bt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    ac = a.reshape(bsz, n_chunks, chunk, e, -1).transpose(1, 0, 2, 3, 4)
    bc = bt.reshape(bsz, n_chunks, chunk, e, -1).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(bsz, n_chunks, chunk, -1).transpose(1, 0, 2, 3)

    def chunk_step(h, xs):
        aj, bj, Cj = xs
        h_all, h_last = _chunk_scan(aj, bj, h)
        yj = jnp.einsum("blen,bln->ble", h_all, Cj)    # contract state dim
        return h_last, yj

    if remat:
        chunk_step = jax.checkpoint(chunk_step)
    h_last, ys = jax.lax.scan(chunk_step, state["ssm"], (ac, bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, n_chunks * chunk, e)[:, :L]
    y = y + u_conv.astype(jnp.float32) * params["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    return out, {"conv": new_conv.astype(state["conv"].dtype), "ssm": h_last}


def mamba_step(params, x, cfg: ModelConfig, state: dict) -> Tuple[jnp.ndarray, dict]:
    """Single-token decode step. x: (b, 1, d)."""
    return mamba_forward(params, x, cfg, state=state, chunk=1, remat=False)
