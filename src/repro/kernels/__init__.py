"""Pallas TPU kernels for the compute hot-spots CDLM optimizes.

- ``block_attn``  — block-causal flash attention (training / prefill);
- ``decode_attn`` — flash-decode of a B-token active block vs the KV cache
                    (the §4.3 serving hot loop), GQA groups folded into
                    query rows for MXU utilization;
- ``xent``        — fused streaming large-vocab softmax cross-entropy
                    (150k–256k-vocab lm-head loss without (T, V) logits).

Each subpackage: ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd model-layout wrapper), ``ref.py`` (pure-jnp oracle). Validated with
``interpret=True`` shape/dtype sweeps in tests/test_kernels.py; on real TPU
pass ``interpret=False``.
"""
from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams (~0.5); support both.
CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    getattr(_pltpu, "TPUCompilerParams")

from repro.kernels import block_attn, decode_attn, xent  # noqa: F401,E402
