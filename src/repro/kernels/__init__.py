"""Pallas TPU kernels for the compute hot-spots CDLM optimizes.

- ``block_attn``  — block-causal flash attention (training / prefill);
- ``decode_attn`` — flash-decode of a B-token active block vs the KV cache
                    (the §4.3 serving hot loop), GQA groups folded into
                    query rows for MXU utilization;
- ``xent``        — fused streaming large-vocab softmax cross-entropy
                    (150k–256k-vocab lm-head loss without (T, V) logits);
- ``select``      — fused unembed + online-softmax candidate selection
                    (the §4.3 decode loop's per-step confidence/argmax
                    without (b, L, V) logits).

Each subpackage: ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd model-layout wrapper), ``ref.py`` (pure-jnp oracle). Validated with
``interpret=True`` shape/dtype sweeps in tests/test_kernels.py /
tests/test_select_kernel.py; every op resolves ``interpret=None`` through
:func:`default_interpret`, so real accelerators compile the kernels and
CPU runs emulate them without call sites having to care.
"""
import jax
from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams (~0.5); support both.
CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    getattr(_pltpu, "TPUCompilerParams")


def default_interpret() -> bool:
    """Backend-aware default for the ``interpret`` flag of every kernel op.

    Every kernel in this repo is TPU-flavored Pallas (``pltpu`` memory
    spaces, compiler params, scalar prefetch), so only a TPU backend can
    actually compile them — everywhere else (CPU tests/CI, GPU) they run
    under the interpreter. Resolved at trace time, so an op called with
    ``interpret=None`` does the right thing on whatever backend jax
    selected."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """``None`` -> :func:`default_interpret`; explicit bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)


from repro.kernels import block_attn, decode_attn, select, xent  # noqa: F401,E402
