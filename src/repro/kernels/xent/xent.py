"""Fused large-vocab softmax cross-entropy — Pallas TPU kernel.

The lm-head loss of the 150k–256k-vocab archs is the single largest
activation in training: materializing (T, V) logits at T = batch×seq is
O(GB). This kernel streams vocab tiles of the head matrix through VMEM,
maintaining the online logsumexp and the target logit in scratch, and never
materializes logits in HBM. The per-token loss is ``logsumexp - logit[y]``.

Grid: (T_tiles, V_tiles), V innermost ("arbitrary"). Each step computes the
(block_t × block_v) logit tile with one MXU matmul from the resident
(block_t × d) hidden tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams, resolve_interpret


def _xent_kernel(h_ref, w_ref, y_ref, loss_ref, m_scr, l_scr, t_scr, *,
                 block_t, block_v, n_v):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        t_scr[...] = jnp.zeros_like(t_scr)

    h = h_ref[...].astype(jnp.float32)                    # (block_t, d)
    w = w_ref[...].astype(jnp.float32)                    # (d, block_v)
    logits = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    vpos = vi * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (block_t, block_v), 1)
    y = y_ref[...].reshape(block_t, 1)                    # (block_t, 1)
    t_scr[...] = t_scr[...] + jnp.sum(
        jnp.where(vpos == y, logits, 0.0), axis=-1, keepdims=True)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(jnp.exp(logits - m_new),
                                              axis=-1, keepdims=True)
    m_scr[...] = m_new

    @pl.when(vi == n_v - 1)
    def _finalize():
        logz = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        loss_ref[...] = (logz - t_scr[...]).reshape(loss_ref.shape)


def xent_forward(hidden, w, targets, *, block_t: int = 128,
                 block_v: int = 512, interpret=None):
    """hidden: (T, d); w: (d, V); targets: (T,) int32 -> loss (T,) fp32.

    T must be a multiple of block_t, V of block_v (ops.py pads)."""
    T, d = hidden.shape
    V = w.shape[1]
    assert T % block_t == 0 and V % block_v == 0
    n_t, n_v = T // block_t, V // block_v

    kernel = functools.partial(_xent_kernel, block_t=block_t,
                               block_v=block_v, n_v=n_v)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((block_t,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((T,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(hidden, w, targets)
