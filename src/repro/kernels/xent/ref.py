"""Oracle: plain full-materialization softmax cross-entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def xent_ref(hidden, w, targets):
    """hidden: (T, d); w: (d, V); targets: (T,) -> per-token loss (T,) fp32."""
    logits = hidden.astype(jnp.float32) @ w.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tl = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return logz - tl
