"""Public fused-xent op with custom VJP.

Forward: the Pallas streaming kernel (no (T, V) logits in HBM). Backward:
the same vocab-tiled schedule expressed as a ``lax.scan`` over vocab chunks
(dh += (p - 1y) @ Wᵀ, dW += hᵀ (p - 1y)), recomputing each logit tile —
identical memory behavior, one more matmul pass (the standard
recompute-softmax trade).

Tuning: knobs resolve through :mod:`repro.kernels.tuning` — pass one
``config=KernelConfig`` (``block_t``/``block_v`` tile the forward kernel,
``chunk`` sets the backward's scan chunk); the positional ``block_t``/
``block_v``/``interpret`` args keep working as deprecated pass-throughs.
Unspecified knobs come from the tuned table per (vocab bucket, backend).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.xent.xent import xent_forward


def _pad_t(x, mult, fill=0):
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x, x.shape[0]
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill), x.shape[0]


def _resolve(V, block_t, block_v, interpret,
             config: Optional[tuning.KernelConfig]):
    cfg = tuning.resolve(
        "xent",
        config=tuning.merge_legacy(config, block_t=block_t, block_v=block_v,
                                   interpret=interpret),
        V=V)
    block_v = cfg.block_v
    if V % block_v != 0:
        # pick the largest tile that divides V (keeps kernel exact)
        for cand in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
            if V % cand == 0:
                block_v = cand
                break
    return cfg.block_t, block_v, cfg.interpret, cfg.chunk


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def fused_xent(hidden, w, targets, block_t: Optional[int] = None,
               block_v: Optional[int] = None, interpret=None,
               config: Optional[tuning.KernelConfig] = None):
    """Per-token cross-entropy (T,) without materializing logits."""
    loss, _ = _fwd(hidden, w, targets, block_t, block_v, interpret, config)
    return loss


def _fwd(hidden, w, targets, block_t, block_v, interpret, config):
    bt, bv, interp, _ = _resolve(w.shape[1], block_t, block_v, interpret,
                                 config)
    hp, T = _pad_t(hidden, bt)
    yp, _ = _pad_t(targets, bt)
    loss = xent_forward(hp, w, yp, block_t=bt, block_v=bv,
                        interpret=interp)[:T]
    return loss, (hidden, w, targets)


def _bwd(block_t, block_v, interpret, config, res, g):
    hidden, w, targets = res
    T, d = hidden.shape
    V = w.shape[1]
    _, bv, _, tuned_chunk = _resolve(V, block_t, block_v, interpret, config)
    chunk = tuned_chunk if tuned_chunk else max(bv, 512)
    while V % chunk != 0:
        chunk //= 2
    n = V // chunk
    hf = hidden.astype(jnp.float32)

    # pass 1: logsumexp stats (recompute, tiled)
    def stat_step(carry, j):
        m, l = carry
        wj = jax.lax.dynamic_slice_in_dim(w, j * chunk, chunk, 1).astype(jnp.float32)
        lo = hf @ wj
        mj = jnp.maximum(m, lo.max(-1, keepdims=True))
        l = l * jnp.exp(m - mj) + jnp.exp(lo - mj).sum(-1, keepdims=True)
        return (mj, l), None

    m0 = jnp.full((T, 1), -jnp.inf)
    (m, l), _ = jax.lax.scan(stat_step, (m0, jnp.zeros((T, 1))), jnp.arange(n))
    logz = m + jnp.log(jnp.maximum(l, 1e-30))

    # pass 2: gradients, tiled
    def grad_step(dh, j):
        wj = jax.lax.dynamic_slice_in_dim(w, j * chunk, chunk, 1).astype(jnp.float32)
        lo = hf @ wj
        p = jnp.exp(lo - logz)
        vpos = j * chunk + jnp.arange(chunk)[None, :]
        p = p - (vpos == targets[:, None])
        p = p * g[:, None]
        dh = dh + p @ wj.T
        dwj = hf.T @ p
        return dh, dwj

    dh, dw_chunks = jax.lax.scan(grad_step, jnp.zeros((T, d)), jnp.arange(n))
    # scan stacks to (n, d, chunk): reorder to (d, V)
    dw = jnp.swapaxes(dw_chunks, 0, 1).reshape(d, V)
    return dh.astype(hidden.dtype), dw.astype(w.dtype), None


fused_xent.defvjp(_fwd, _bwd)
