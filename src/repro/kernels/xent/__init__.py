from repro.kernels.xent.ops import fused_xent  # noqa: F401
from repro.kernels.xent.ref import xent_ref  # noqa: F401
from repro.kernels.xent.xent import xent_forward  # noqa: F401
