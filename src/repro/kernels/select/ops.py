"""Public fused-select op (decode-only, no VJP).

``fused_select`` maps model-layout hidden states ``(..., d)`` plus the
unembedding matrix ``(d, V)`` to ``(candidate ids, confidences)`` of shape
``(...)`` without ever materializing ``(..., V)`` logits:

- ``impl='pallas'``    — the vocab-tiled Pallas kernel (``select.py``);
  compiled on accelerators, interpreted elsewhere (``interpret=None``
  resolves through ``kernels.default_interpret``).
- ``impl='streaming'`` — the identical online-statistics algorithm as a
  jit-compiled ``lax.scan`` over vocab chunks (``ref.select_streaming``);
  this is the fast fused path on CPU, where interpreting the Pallas kernel
  would cost more than the HBM traffic it saves.
- ``impl='auto'``      — pallas on TPU, streaming otherwise.

Both implementations share first-occurrence argmax tie-breaking with
``jnp.argmax`` and emit confidences equal to the dense
softmax-probability-of-argmax up to fp32 reduction order.

Tuning: all knobs live on one :class:`repro.kernels.tuning.KernelConfig`
consumed via ``config=``; the per-knob kwargs (``block_t``/``block_v``/
``impl``/``interpret``) are deprecated pass-throughs that override config
fields when passed. With neither given, the knobs resolve from the tuned
table per ``(vocab bucket, backend)`` — see ``repro.kernels.tuning``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.select.ref import select_streaming
from repro.kernels.select.select import select_forward

IMPLS = ("auto", "pallas", "streaming")


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("softcap", "block_t", "block_v", "impl", "interpret",
                     "config"))
def fused_select(hidden, w, masked, *, softcap: Optional[float] = None,
                 block_t: Optional[int] = None,
                 block_v: Optional[int] = None, impl: Optional[str] = None,
                 interpret: Optional[bool] = None,
                 config: Optional[tuning.KernelConfig] = None):
    """hidden: (..., d); w: (d, V); masked: (...) bool ->
    (cand (...) int32, conf (...) fp32).

    Greedy candidate = argmax over the fused logits; confidence = its
    softmax probability; rows with ``masked == False`` (already finalized)
    get -inf confidence, matching ``diffusion.confidence_and_candidates``
    at temperature 0."""
    cfg = tuning.resolve(
        "select",
        config=tuning.merge_legacy(config, block_t=block_t, block_v=block_v,
                                   impl=impl, interpret=interpret),
        V=w.shape[1])
    if cfg.impl not in IMPLS:
        raise ValueError(f"unknown fused_select impl {cfg.impl!r} "
                         f"(expected one of {IMPLS})")
    impl = cfg.impl
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "streaming"
    lead = hidden.shape[:-1]
    h2 = hidden.reshape(-1, hidden.shape[-1])
    m2 = masked.reshape(-1)
    if impl == "streaming":
        cand, conf = select_streaming(h2, w, m2, softcap=softcap,
                                      chunk=cfg.chunk or cfg.block_v)
    else:
        T = h2.shape[0]
        V = w.shape[1]
        hp = _pad_axis(h2, 0, cfg.block_t)
        mp = _pad_axis(m2.astype(jnp.int32), 0, cfg.block_t)
        wp = _pad_axis(w, 1, cfg.block_v)
        cand, conf = select_forward(hp, wp, mp, v_total=V, softcap=softcap,
                                    block_t=cfg.block_t, block_v=cfg.block_v,
                                    interpret=cfg.interpret)
        cand, conf = cand[:T], conf[:T]
    return cand.reshape(lead), conf.reshape(lead)
