"""Public fused-select op (decode-only, no VJP).

``fused_select`` maps model-layout hidden states ``(..., d)`` plus the
unembedding matrix ``(d, V)`` to ``(candidate ids, confidences)`` of shape
``(...)`` without ever materializing ``(..., V)`` logits:

- ``impl='pallas'``    — the vocab-tiled Pallas kernel (``select.py``);
  compiled on accelerators, interpreted elsewhere (``interpret=None``
  resolves through ``kernels.default_interpret``).
- ``impl='streaming'`` — the identical online-statistics algorithm as a
  jit-compiled ``lax.scan`` over vocab chunks (``ref.select_streaming``);
  this is the fast fused path on CPU, where interpreting the Pallas kernel
  would cost more than the HBM traffic it saves.
- ``impl='auto'``      — pallas on TPU, streaming otherwise.

Both implementations share first-occurrence argmax tie-breaking with
``jnp.argmax`` and emit confidences equal to the dense
softmax-probability-of-argmax up to fp32 reduction order.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.select.ref import select_streaming
from repro.kernels.select.select import select_forward

IMPLS = ("auto", "pallas", "streaming")


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("softcap", "block_t", "block_v", "impl", "interpret"))
def fused_select(hidden, w, masked, *, softcap: Optional[float] = None,
                 block_t: int = 128, block_v: int = 512, impl: str = "auto",
                 interpret: Optional[bool] = None):
    """hidden: (..., d); w: (d, V); masked: (...) bool ->
    (cand (...) int32, conf (...) fp32).

    Greedy candidate = argmax over the fused logits; confidence = its
    softmax probability; rows with ``masked == False`` (already finalized)
    get -inf confidence, matching ``diffusion.confidence_and_candidates``
    at temperature 0."""
    if impl not in IMPLS:
        raise ValueError(f"unknown fused_select impl {impl!r} "
                         f"(expected one of {IMPLS})")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "streaming"
    lead = hidden.shape[:-1]
    h2 = hidden.reshape(-1, hidden.shape[-1])
    m2 = masked.reshape(-1)
    if impl == "streaming":
        cand, conf = select_streaming(h2, w, m2, softcap=softcap,
                                      chunk=block_v)
    else:
        T = h2.shape[0]
        V = w.shape[1]
        hp = _pad_axis(h2, 0, block_t)
        mp = _pad_axis(m2.astype(jnp.int32), 0, block_t)
        wp = _pad_axis(w, 1, block_v)
        cand, conf = select_forward(hp, wp, mp, v_total=V, softcap=softcap,
                                    block_t=block_t, block_v=block_v,
                                    interpret=interpret)
        cand, conf = cand[:T], conf[:T]
    return cand.reshape(lead), conf.reshape(lead)
