from repro.kernels.select.ops import fused_select  # noqa: F401
from repro.kernels.select.ref import (  # noqa: F401
    select_ref,
    select_streaming,
)
from repro.kernels.select.select import select_forward  # noqa: F401
