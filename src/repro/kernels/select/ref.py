"""Oracles for the fused select kernel.

``select_ref`` is the dense baseline: full ``(T, V)`` logits, fp32 softmax,
argmax + gather — exactly the math of ``models.layers.lm_head`` followed by
``diffusion.confidence_and_candidates`` at temperature 0.

``select_streaming`` is the same online-statistics algorithm as the Pallas
kernel expressed as a ``lax.scan`` over vocab chunks — it never
materializes ``(T, V)`` either, compiles on every backend, and doubles as
the fused path on CPU (where the Pallas kernel would run interpreted).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

def _softcap(x, cap: Optional[float]):
    return x if cap is None else cap * jnp.tanh(x / cap)


def select_ref(hidden, w, masked, *, softcap: Optional[float] = None):
    """hidden: (T, d); w: (d, V); masked: (T,) bool
    -> (cand (T,) int32, conf (T,) fp32; finalized rows get -inf conf)."""
    logits = _softcap(hidden.astype(jnp.float32) @ w.astype(jnp.float32),
                      softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    cand = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    conf = jnp.take_along_axis(probs, cand[:, None], axis=-1)[:, 0]
    return cand, jnp.where(masked, conf, -jnp.inf)


def select_streaming(hidden, w, masked, *, softcap: Optional[float] = None,
                     chunk: int = 512):
    """Vocab-chunked scan with running (max, sum-exp, argmax) — no (T, V)
    intermediate. Same outputs as :func:`select_ref` up to fp reduction
    order."""
    T, _ = hidden.shape
    V = w.shape[1]
    chunk = min(chunk, V)
    n = -(-V // chunk)
    pad = n * chunk - V
    wp = jnp.pad(w, ((0, 0), (0, pad))) if pad else w
    hf = hidden.astype(jnp.float32)

    def step(carry, j):
        m, l, bi = carry
        wj = jax.lax.dynamic_slice_in_dim(wp, j * chunk, chunk, 1)
        lo = _softcap(hf @ wj.astype(jnp.float32), softcap)
        vpos = j * chunk + jnp.arange(chunk)[None, :]
        lo = jnp.where(vpos < V, lo, -jnp.inf)
        tile_m = jnp.max(lo, axis=-1, keepdims=True)
        tile_i = jnp.min(jnp.where(lo == tile_m, vpos, 2**31 - 1),
                         axis=-1, keepdims=True)
        m_new = jnp.maximum(m, tile_m)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        l = l * alpha + jnp.sum(jnp.exp(lo - m_new), axis=-1, keepdims=True)
        bi = jnp.where(tile_m > m, tile_i, bi)
        return (m_new, l, bi), None

    carry0 = (jnp.full((T, 1), -jnp.inf),
              jnp.zeros((T, 1)),
              jnp.zeros((T, 1), jnp.int32))
    (_, l, bi), _ = jax.lax.scan(step, carry0, jnp.arange(n))
    conf = 1.0 / l[:, 0]
    return bi[:, 0], jnp.where(masked, conf, -jnp.inf)
