"""Fused unembed + online-softmax candidate selection — Pallas TPU kernel.

The CDLM refinement step (paper §4.3, Alg. 1 line 11) needs exactly two
numbers per position: the argmax token of ``p_theta(x0|x_t)`` and its
probability. The baseline path materializes ``(b, L, V)`` logits in HBM
(``lm_head``), re-reads them for a full fp32 softmax, and reads them again
for the argmax/gather — at Dream/LLaDA vocabs (V ≳ 100k) that is several
times more HBM traffic than the whole cached attention pass. This kernel
streams vocab tiles of the unembedding matrix through VMEM the way
``kernels/xent`` does for the training loss: each grid step computes one
``(block_t × block_v)`` logit tile with a single MXU matmul and folds it
into flash-style running statistics

- ``m``  — running max logit,
- ``l``  — running sum of ``exp(logit - m)`` (rescaled on max updates),
- ``i``  — running argmax in global vocab coordinates
           (first-occurrence tie-break, matching ``jnp.argmax``),

so the only HBM writes are the ``(T,)`` candidate ids and ``(T,)``
confidences. The argmax logit *is* the running max, so its softmax
probability finalizes to ``1 / l`` — no second pass.

Rows whose canvas token is already finalized (``mask == 0``) get ``-inf``
confidence in-kernel, matching ``diffusion.confidence_and_candidates``
(unmasked positions are never re-finalized).

Grid: (T_tiles, V_tiles), V innermost ("arbitrary"). Supports gemma-style
final-logit softcap and bf16 hidden/weights with fp32 accumulation. Vocab
padding columns (``vpos >= v_total``) are masked to ``-inf`` in-kernel, so
any V works regardless of tile divisibility.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams, resolve_interpret

def _select_kernel(h_ref, w_ref, mask_ref, cand_ref, conf_ref,
                   m_scr, l_scr, i_scr, *, block_t, block_v, n_v, v_total,
                   softcap):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        i_scr[...] = jnp.zeros_like(i_scr)

    h = h_ref[...].astype(jnp.float32)                    # (block_t, d)
    w = w_ref[...].astype(jnp.float32)                    # (d, block_v)
    logits = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    vpos = vi * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (block_t, block_v), 1)
    logits = jnp.where(vpos < v_total, logits, -jnp.inf)

    m_prev = m_scr[...]
    tile_m = jnp.max(logits, axis=-1, keepdims=True)      # (block_t, 1)
    # first-occurrence argmax of the tile, in global vocab coordinates
    tile_i = jnp.min(jnp.where(logits == tile_m, vpos, 2**31 - 1),
                     axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, tile_m)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(jnp.exp(logits - m_new),
                                              axis=-1, keepdims=True)
    # strict > keeps the earlier tile's index on cross-tile ties, matching
    # jnp.argmax's first-occurrence semantics over the full row
    i_scr[...] = jnp.where(tile_m > m_prev, tile_i, i_scr[...])
    m_scr[...] = m_new

    @pl.when(vi == n_v - 1)
    def _finalize():
        # the argmax logit is the running max, so softmax(conf) = 1/l
        conf = 1.0 / l_scr[...]
        live = mask_ref[...].reshape(block_t, 1) != 0
        conf_ref[...] = jnp.where(live, conf, -jnp.inf).reshape(conf_ref.shape)
        cand_ref[...] = i_scr[...].reshape(cand_ref.shape)


def select_forward(hidden, w, masked, *, v_total: Optional[int] = None,
                   softcap: Optional[float] = None, block_t: int = 128,
                   block_v: int = 512, interpret: Optional[bool] = None):
    """hidden: (T, d); w: (d, Vp); masked: (T,) int32 (0 = finalized row)
    -> (cand (T,) int32, conf (T,) fp32).

    T must be a multiple of block_t and Vp of block_v (ops.py pads);
    ``v_total`` is the true vocab size — columns at/after it are padding
    and masked to -inf in-kernel."""
    T, d = hidden.shape
    Vp = w.shape[1]
    v_total = Vp if v_total is None else v_total
    assert T % block_t == 0 and Vp % block_v == 0, (T, Vp, block_t, block_v)
    assert v_total <= Vp
    n_t, n_v = T // block_t, Vp // block_v

    kernel = functools.partial(_select_kernel, block_t=block_t,
                               block_v=block_v, n_v=n_v, v_total=v_total,
                               softcap=softcap)
    return pl.pallas_call(
        kernel,
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((block_t,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda i, j: (i,)),
            pl.BlockSpec((block_t,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.float32),
            pltpu.VMEM((block_t, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(hidden, w, masked)
