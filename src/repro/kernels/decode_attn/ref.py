"""Pure-jnp oracle for the flash-decode kernel: a B-token active block
attending to a (dynamically valid) KV cache plus its own fresh block KV."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, k_blk, v_blk, cache_len, *,
                         scale: float = 1.0, softcap: Optional[float] = None,
                         window: Optional[int] = None):
    """q: (b, Bq, Kv, G, hd); caches: (b, S, Kv, hd); block kv: (b, Bq, Kv, hd).

    Query i sits at absolute position cache_len + i; cache slot s holds
    position s (valid iff s < cache_len); within-block attention is
    bidirectional (CDLM refinement). Returns (b, Bq, Kv, G, hd) fp32."""
    b, Bq, Kv, G, hd = q.shape
    S = k_cache.shape[1]
    k_all = jnp.concatenate([k_cache, k_blk], axis=1)
    v_all = jnp.concatenate([v_cache, v_blk], axis=1)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                   k_all.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kv_pos = jnp.concatenate([jnp.arange(S), cache_len + jnp.arange(Bq)])
    valid = jnp.concatenate([jnp.arange(S) < cache_len, jnp.ones((Bq,), bool)])
    q_pos = cache_len + jnp.arange(Bq)
    vis = valid[None, :] & jnp.ones((Bq, 1), bool)
    if window is not None:
        vis = vis & (jnp.abs(q_pos[:, None] - kv_pos[None, :]) < window)
    s = jnp.where(vis[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v_all.astype(jnp.float32))
