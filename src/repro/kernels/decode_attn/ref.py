"""Pure-jnp oracle for the flash-decode kernel: a B-token active block
attending to a (dynamically valid) KV cache plus its own fresh block KV."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, k_blk, v_blk, cache_len, *,
                         scale: float = 1.0, softcap: Optional[float] = None,
                         window: Optional[int] = None):
    """q: (b, Bq, Kv, G, hd); caches: (b, S, Kv, hd); block kv: (b, Bq, Kv, hd).

    Query i sits at absolute position cache_len + i; cache slot s holds
    position s (valid iff s < cache_len); within-block attention is
    bidirectional (CDLM refinement). Returns (b, Bq, Kv, G, hd) fp32."""
    b, Bq, Kv, G, hd = q.shape
    S = k_cache.shape[1]
    k_all = jnp.concatenate([k_cache, k_blk], axis=1)
    v_all = jnp.concatenate([v_cache, v_blk], axis=1)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                   k_all.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kv_pos = jnp.concatenate([jnp.arange(S), cache_len + jnp.arange(Bq)])
    valid = jnp.concatenate([jnp.arange(S) < cache_len, jnp.ones((Bq,), bool)])
    q_pos = cache_len + jnp.arange(Bq)
    vis = valid[None, :] & jnp.ones((Bq, 1), bool)
    if window is not None:
        vis = vis & (jnp.abs(q_pos[:, None] - kv_pos[None, :]) < window)
    s = jnp.where(vis[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v_all.astype(jnp.float32))


def paged_decode_attention_ref(q, k_pages, v_pages, k_blk, v_blk, page_table,
                               cache_lens, *, scale: float = 1.0,
                               softcap: Optional[float] = None,
                               window: Optional[int] = None):
    """Oracle for the paged kernel: gather each lane's pages into a dense
    per-lane cache, then reuse the dense oracle lane by lane (per-lane
    ``cache_lens`` — lanes decode at different block offsets).

    q: (b, Bq, Kv, G, hd); pools: (n_pages, page, Kv, hd);
    page_table: (b, n_tables); cache_lens: scalar or (b,) int32."""
    b = q.shape[0]
    n_pages, page = k_pages.shape[0], k_pages.shape[1]
    n_t = page_table.shape[1]
    lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))
    tbl = jnp.clip(page_table, 0, n_pages - 1)
    kc = k_pages[tbl].reshape(b, n_t * page, *k_pages.shape[2:])
    vc = v_pages[tbl].reshape(b, n_t * page, *v_pages.shape[2:])
    outs = [
        decode_attention_ref(q[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                             k_blk[i:i + 1], v_blk[i:i + 1], lens[i],
                             scale=scale, softcap=softcap, window=window)
        for i in range(b)
    ]
    return jnp.concatenate(outs, axis=0)
