from repro.kernels.decode_attn.decode_attn import (  # noqa: F401
    decode_attention_partial,
    paged_decode_attention_partial,
)
from repro.kernels.decode_attn.ops import (  # noqa: F401
    decode_attention,
    paged_decode_attention,
    softmax_combine,
)
from repro.kernels.decode_attn.ref import (  # noqa: F401
    decode_attention_ref,
    paged_decode_attention_ref,
)
