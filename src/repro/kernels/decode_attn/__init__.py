from repro.kernels.decode_attn.decode_attn import decode_attention_partial  # noqa: F401
from repro.kernels.decode_attn.ops import decode_attention, softmax_combine  # noqa: F401
from repro.kernels.decode_attn.ref import decode_attention_ref  # noqa: F401
