"""Jit'd wrapper: full CDLM decode-step attention = kernel partials over the
cache ⊕ in-block bidirectional part, combined by online-softmax merge.

``decode_attention`` reads a dense per-lane cache; ``paged_decode_attention``
reads a block-paged pool through per-lane page tables (and takes *per-lane*
cache lengths, since paged decode serves lanes at mixed block offsets).

Tuning: both ops take ``config=KernelConfig`` (see
:mod:`repro.kernels.tuning`). For the dense kernel ``block_k`` is the cache
tile; the paged kernel's page tile and lane grid are fixed by the pool's
``page_size`` and page-table shape (chosen by the serving engine), so only
``interpret`` resolves from the table there. The legacy ``block_k``/
``interpret`` kwargs stay as deprecated pass-throughs."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.decode_attn.decode_attn import (
    NEG_INF,
    decode_attention_partial,
    paged_decode_attention_partial,
)


def softmax_combine(parts):
    """Merge [(acc, m, l), ...] unnormalized online-softmax partials.

    Shared by this kernel and the sequence-parallel sharded decode
    (repro.parallel.seq_decode)."""
    m = functools.reduce(jnp.maximum, [p[1] for p in parts])
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    acc = sum(p[0] * jnp.where(jnp.isfinite(p[1]), jnp.exp(p[1] - m_safe), 0.0)
              for p in parts)
    l = sum(p[2] * jnp.where(jnp.isfinite(p[1]), jnp.exp(p[1] - m_safe), 0.0)
            for p in parts)
    return acc / jnp.maximum(l, 1e-30)


def _block_partial(q, k_blk, v_blk, *, scale, softcap, window, g):
    """In-block (Bq×Bq) attention partials in plain jnp — tiny."""
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k_blk.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if window is not None:
        BqG, Bq = q.shape[1], k_blk.shape[1]
        qpos = jnp.arange(BqG)[:, None] // g
        kpos = jnp.arange(Bq)[None, :]
        s = jnp.where(jnp.abs(qpos - kpos) < window, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bqk,bkh->bqh", p, v_blk.astype(jnp.float32))
    return acc, m, l


@functools.partial(
    jax.jit,
    static_argnames=("scale", "softcap", "window", "block_k", "interpret",
                     "config"))
def decode_attention(q, k_cache, v_cache, k_blk, v_blk, cache_len, *,
                     scale: float = 1.0, softcap: Optional[float] = None,
                     window: Optional[int] = None,
                     block_k: Optional[int] = None,
                     interpret: Optional[bool] = None,
                     config: Optional[tuning.KernelConfig] = None):
    """Model-layout decode attention.

    q: (b, Bq, Kv, G, hd); k/v_cache: (b, S, Kv, hd); k/v_blk: (b, Bq, Kv, hd);
    cache_len: scalar int32 — valid cache prefix. Returns (b, Bq, Kv, G, hd).
    """
    b, Bq, Kv, G, hd = q.shape
    S = k_cache.shape[1]
    cfg = tuning.resolve(
        "decode_attn",
        config=tuning.merge_legacy(config, block_k=block_k,
                                   interpret=interpret),
        S=S)
    block_k, interpret = cfg.block_k, cfg.interpret
    if S % block_k != 0:
        # the kernel requires S to tile exactly; fall back to the largest
        # dividing tile so tuned configs never break odd cache lengths
        while S % block_k != 0:
            block_k //= 2
    qf = q.transpose(0, 2, 1, 3, 4).reshape(b * Kv, Bq * G, hd)
    kcf = k_cache.transpose(0, 2, 1, 3).reshape(b * Kv, S, hd)
    vcf = v_cache.transpose(0, 2, 1, 3).reshape(b * Kv, S, hd)
    kbf = k_blk.transpose(0, 2, 1, 3).reshape(b * Kv, Bq, hd)
    vbf = v_blk.transpose(0, 2, 1, 3).reshape(b * Kv, Bq, hd)

    cache_part = decode_attention_partial(
        qf, kcf, vcf, cache_len, scale=scale, softcap=softcap, window=window,
        g=G, block_k=block_k, interpret=interpret)
    blk_part = _block_partial(qf, kbf, vbf, scale=scale, softcap=softcap,
                              window=window, g=G)
    out = softmax_combine([cache_part, blk_part])
    return out.reshape(b, Kv, Bq, G, hd).transpose(0, 2, 1, 3, 4)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "softcap", "window", "interpret", "config"))
def paged_decode_attention(q, k_pages, v_pages, k_blk, v_blk, page_table,
                           cache_lens, *, scale: float = 1.0,
                           softcap: Optional[float] = None,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None,
                           config: Optional[tuning.KernelConfig] = None):
    """Model-layout decode attention over a block-paged KV pool.

    q: (b, Bq, Kv, G, hd); k/v_pages: (n_pages, page, Kv, hd) pools shared
    across lanes; k/v_blk: (b, Bq, Kv, hd) fresh in-block KV;
    page_table: (b, n_tables) int32 (-1 = unallocated); cache_lens: scalar
    or (b,) int32 — per-lane valid cache prefix. Returns (b, Bq, Kv, G, hd).
    """
    b, Bq, Kv, G, hd = q.shape
    cfg = tuning.resolve(
        "decode_attn",
        config=tuning.merge_legacy(config, interpret=interpret),
        S=page_table.shape[1] * k_pages.shape[1])
    interpret = cfg.interpret
    qf = q.transpose(0, 2, 1, 3, 4).reshape(b, Kv, Bq * G, hd)
    kp = k_pages.transpose(2, 0, 1, 3)        # (Kv, n_pages, page, hd)
    vp = v_pages.transpose(2, 0, 1, 3)
    kbf = k_blk.transpose(0, 2, 1, 3).reshape(b * Kv, Bq, hd)
    vbf = v_blk.transpose(0, 2, 1, 3).reshape(b * Kv, Bq, hd)

    acc, m, l = paged_decode_attention_partial(
        qf, kp, vp, page_table, cache_lens, scale=scale, softcap=softcap,
        window=window, g=G, interpret=interpret)
    cache_part = (acc.reshape(b * Kv, Bq * G, hd),
                  m.reshape(b * Kv, Bq * G, 1),
                  l.reshape(b * Kv, Bq * G, 1))
    blk_part = _block_partial(qf.reshape(b * Kv, Bq * G, hd), kbf, vbf,
                              scale=scale, softcap=softcap, window=window,
                              g=G)
    out = softmax_combine([cache_part, blk_part])
    return out.reshape(b, Kv, Bq, G, hd).transpose(0, 2, 1, 3, 4)
