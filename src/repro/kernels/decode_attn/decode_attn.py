"""Flash-decode Pallas kernel: active-block queries vs the KV cache.

TPU adaptation (DESIGN.md §4): a CDLM decode step is a B=32-token query
block against a long cache. We fold the GQA group dimension into the query
rows — per KV head the MXU sees a (B·G, hd) × (hd, block_k) matmul, so even
B=32 with G=8 fills a 256-row tile (vs 32 wasted-lane rows if G stayed a
broadcast dim). The cache length is dynamic: tiles entirely beyond
``cache_len`` are skipped (``pl.when``), the boundary tile is masked by
iota comparison.

The kernel returns *unnormalized* online-softmax partials (acc, m, l) so
the caller can combine them with the fresh in-block attention part (tiny,
B×B, done in jnp) — the same (num, denom, max) combination used by the
sequence-parallel sharded decode in ``repro.parallel``, so single-chip and
distributed paths share one correctness story.

Two cache layouts share the online-softmax body:

- :func:`decode_attention_partial` — dense per-lane ``(bKv, S, hd)``
  buffers, contiguous KV tiles;
- :func:`paged_decode_attention_partial` — a block-paged pool
  ``(Kv, n_pages, page, hd)`` shared across lanes. The grid's KV dimension
  walks each lane's *page table* instead of a contiguous buffer: the table
  (scalar-prefetched to SMEM) feeds the K/V BlockSpec index_map, so tile j
  DMAs pool page ``table[lane, j]``; table entries past the lane's
  ``cache_len`` (including unallocated ``-1`` slots, clamped to a valid DMA)
  are skipped with ``pl.when``. ``cache_len`` is per-lane — lanes in one
  batch decode at different block offsets (continuous batching).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams, resolve_interpret

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                   acc_scr, m_scr, l_scr, *, scale, softcap, window, g: int,
                   block_k: int, n_k: int):
    ki = pl.program_id(1)
    cache_len = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(ki * block_k < cache_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale      # (BqG, hd)
        k = k_ref[0].astype(jnp.float32)              # (block_k, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        rows = s.shape[0]
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (rows, block_k), 1)
        vis = kpos < cache_len
        if window is not None:
            qpos = cache_len + jax.lax.broadcasted_iota(
                jnp.int32, (rows, block_k), 0) // g
            vis = vis & (qpos - kpos < window)
        s = jnp.where(vis, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        m_scr[...] = m_new
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _finalize():
        acc_ref[0] = acc_scr[...]
        m_ref[0] = m_scr[...]
        l_ref[0] = l_scr[...]


def decode_attention_partial(q, k_cache, v_cache, cache_len, *,
                             scale: float = 1.0,
                             softcap: Optional[float] = None,
                             window: Optional[int] = None, g: int = 1,
                             block_k: int = 128,
                             interpret: Optional[bool] = None):
    """q: (bKv, BqG, hd); cache: (bKv, S, hd); cache_len: scalar int32.

    Returns unnormalized partials (acc (bKv, BqG, hd), m (bKv, BqG, 1),
    l (bKv, BqG, 1)) over cache slots < cache_len."""
    bKv, BqG, hd = q.shape
    S = k_cache.shape[1]
    assert S % block_k == 0
    n_k = S // block_k
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (1,))

    kernel = functools.partial(_decode_kernel, scale=scale, softcap=softcap,
                               window=window, g=g, block_k=block_k, n_k=n_k)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(bKv, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, BqG, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BqG, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, BqG, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, BqG, 1), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bKv, BqG, hd), jnp.float32),
            jax.ShapeDtypeStruct((bKv, BqG, 1), jnp.float32),
            jax.ShapeDtypeStruct((bKv, BqG, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((BqG, hd), jnp.float32),
            pltpu.VMEM((BqG, 1), jnp.float32),
            pltpu.VMEM((BqG, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(lens, q, k_cache, v_cache)
    return acc, m, l


# ---------------------------------------------------------------------------
# Paged variant
# ---------------------------------------------------------------------------
def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, acc_ref,
                         m_ref, l_ref, acc_scr, m_scr, l_scr, *, scale,
                         softcap, window, g: int, page: int, n_t: int):
    bi = pl.program_id(0)
    ji = pl.program_id(2)
    cache_len = len_ref[bi]

    @pl.when(ji == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # page j of lane bi covers positions [j*page, (j+1)*page); a page that
    # starts at/after cache_len holds nothing visible — in particular every
    # unallocated (-1) table slot, since committed positions always have
    # pages. pl.when skips its compute entirely.
    @pl.when((ji * page < cache_len) & (pt_ref[bi, ji] >= 0))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # (BqG, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (page, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = s.shape[0]
        kpos = ji * page + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page), 1)
        vis = kpos < cache_len
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        if window is not None:
            qpos = cache_len + jax.lax.broadcasted_iota(
                jnp.int32, (rows, page), 0) // g
            vis = vis & (qpos - kpos < window)
        s = jnp.where(vis, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        m_scr[...] = m_new
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ji == n_t - 1)
    def _finalize():
        acc_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


def paged_decode_attention_partial(q, k_pages, v_pages, page_table,
                                   cache_lens, *, scale: float = 1.0,
                                   softcap: Optional[float] = None,
                                   window: Optional[int] = None, g: int = 1,
                                   interpret: Optional[bool] = None):
    """q: (b, Kv, BqG, hd); pools: (Kv, n_pages, page, hd);
    page_table: (b, n_t) int32 (-1 = unallocated); cache_lens: (b,) int32
    per-lane valid prefix.

    Returns unnormalized partials (acc (b, Kv, BqG, hd), m (b, Kv, BqG, 1),
    l (b, Kv, BqG, 1)) over each lane's cache slots < cache_lens[lane]."""
    b, Kv, BqG, hd = q.shape
    n_pages, page = k_pages.shape[1], k_pages.shape[2]
    n_t = page_table.shape[1]
    pt = jnp.asarray(page_table, jnp.int32)
    lens = jnp.broadcast_to(jnp.asarray(cache_lens, jnp.int32), (b,))

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               softcap=softcap, window=window, g=g,
                               page=page, n_t=n_t)

    def page_idx(bi, ki, ji, pt_ref, len_ref):
        # unallocated slots clamp to page 0: a valid DMA whose compute is
        # pl.when-skipped
        return (ki, jnp.maximum(pt_ref[bi, ji], 0), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, Kv, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, BqG, hd),
                         lambda bi, ki, ji, pt_ref, len_ref: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, page, hd), page_idx),
            pl.BlockSpec((1, 1, page, hd), page_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, BqG, hd),
                         lambda bi, ki, ji, pt_ref, len_ref: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, BqG, 1),
                         lambda bi, ki, ji, pt_ref, len_ref: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, BqG, 1),
                         lambda bi, ki, ji, pt_ref, len_ref: (bi, ki, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((BqG, hd), jnp.float32),
            pltpu.VMEM((BqG, 1), jnp.float32),
            pltpu.VMEM((BqG, 1), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, Kv, BqG, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, Kv, BqG, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, Kv, BqG, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(pt, lens, q, k_pages, v_pages)
    return acc, m, l
