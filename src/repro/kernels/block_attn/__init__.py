from repro.kernels.block_attn.block_attn import block_attention  # noqa: F401
from repro.kernels.block_attn.ops import flash_block_attention  # noqa: F401
from repro.kernels.block_attn.ref import block_attention_ref  # noqa: F401
