"""Block-causal flash attention — Pallas TPU kernel.

TPU adaptation of the paper's student attention (DESIGN.md §4): the
block-causal mask is evaluated *tile-wise*. With MXU-aligned tiles
(block_q × block_k = 128×128 by default) a (q-tile, k-tile) pair is either

- fully visible   (k-block entirely before the q-tile's earliest CDLM block,
                   or bidirectional mode)        -> plain matmul, no select;
- fully hidden    (k-block entirely after the latest visible block)
                   -> tile skipped by the visibility predicate;
- boundary        -> per-element mask from broadcasted iotas.

The online-softmax accumulator (m, l, acc) lives in fp32 VMEM scratch; the
k-tile loop is the innermost ("arbitrary") grid dimension so the MXU stays
busy while VMEM streams KV tiles from HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams, resolve_interpret

NEG_INF = -1e30


def _tile_visibility(qi, ki, *, block_q, block_k, mode, prompt_len,
                     block_size, window):
    """Per-element (block_q, block_k) visibility for tile (qi, ki)."""
    q = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    if mode == "bidirectional":
        vis = jnp.ones((block_q, block_k), bool)
    elif mode == "causal":
        vis = k <= q
    else:  # block_causal
        qb = jnp.where(q < prompt_len, -1, (q - prompt_len) // block_size)
        kb = jnp.where(k < prompt_len, -1, (k - prompt_len) // block_size)
        vis = kb <= qb
    if window is not None:
        if mode == "causal":
            vis = vis & (q - k < window)
        else:
            vis = vis & (jnp.abs(q - k) < window)
    return vis


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, softcap, mode, prompt_len, block_size, window,
                  block_q, block_k, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (block_q, d)
    k = k_ref[0].astype(jnp.float32)                  # (block_k, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    vis = _tile_visibility(qi, ki, block_q=block_q, block_k=block_k,
                           mode=mode, prompt_len=prompt_len,
                           block_size=block_size, window=window)
    s = jnp.where(vis, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def block_attention(q, k, v, *, mode: str = "block_causal",
                    prompt_len: int = 0, block_size: int = 1,
                    window: Optional[int] = None, scale: float = 1.0,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, g: int = 1,
                    interpret: Optional[bool] = None):
    """q: (bh, L, d) — batch×q-heads flattened; k/v: (bh // g, L, d) — KV
    heads *not* expanded: query head ``h`` reads KV head ``h // g`` through
    the BlockSpec index map (in-kernel GQA head-group indexing), so the
    G-fold repeat never exists in HBM. L must be a multiple of the tile
    sizes (ops.py pads). Returns (bh, L, d).
    """
    bh, Lq, d = q.shape
    Lk = k.shape[1]
    assert Lq % block_q == 0 and Lk % block_k == 0, (Lq, Lk, block_q, block_k)
    assert bh == k.shape[0] * g, (bh, k.shape[0], g)
    n_q, n_k = Lq // block_q, Lk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, softcap=softcap, mode=mode,
        prompt_len=prompt_len, block_size=block_size, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, Lq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(q, k, v)
