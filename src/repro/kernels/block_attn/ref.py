"""Pure-jnp oracle for the block-causal flash-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def visibility(Lq: int, Lk: int, *, mode: str, prompt_len: int,
               block_size: int, window: Optional[int]) -> jnp.ndarray:
    q = jnp.arange(Lq)[:, None]
    k = jnp.arange(Lk)[None, :]
    if mode == "bidirectional":
        vis = jnp.ones((Lq, Lk), bool)
    elif mode == "causal":
        vis = k <= q
    elif mode == "block_causal":
        qb = jnp.where(q < prompt_len, -1, (q - prompt_len) // block_size)
        kb = jnp.where(k < prompt_len, -1, (k - prompt_len) // block_size)
        vis = kb <= qb
    else:
        raise ValueError(mode)
    if window is not None:
        if mode == "causal":
            vis = vis & (q - k < window)
        else:
            vis = vis & (jnp.abs(q - k) < window)
    return vis


def block_attention_ref(q, k, v, *, mode: str = "block_causal",
                        prompt_len: int = 0, block_size: int = 1,
                        window: Optional[int] = None, scale: float = 1.0,
                        softcap: Optional[float] = None) -> jnp.ndarray:
    """q: (b, h, Lq, d); k/v: (b, h, Lk, d) — heads pre-broadcast (GQA
    expansion happens in ops.py). Returns (b, h, Lq, d) fp32."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    vis = visibility(q.shape[2], k.shape[2], mode=mode, prompt_len=prompt_len,
                     block_size=block_size, window=window)
    s = jnp.where(vis[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
