"""Jit'd public wrapper for the block-causal flash-attention kernel.

Handles the model-side GQA layout (b, L, Kv, G, hd), pads L to the tile
grid (padded KV rows are masked out by position — they land in the
"future" of every real query under causal/block-causal; for bidirectional
we pass an explicit valid length via a window trick is not needed because
padded queries are discarded and padded keys get NEG_INF through the
``kv_len`` argument) and flattens batch×heads. GQA KV heads are *not*
expanded — the kernel indexes KV head ``h // G`` for query head ``h`` in
its BlockSpec index map, so no G-fold KV copy is materialized in HBM.

Tuning: ``block_q``/``block_k``/``interpret`` resolve through one
``config=KernelConfig`` (see :mod:`repro.kernels.tuning`); the per-knob
kwargs remain as deprecated pass-throughs that win over config fields.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.block_attn.block_attn import block_attention


def _pad_to(x, axis, mult):
    L = x.shape[axis]
    pad = (-L) % mult
    if pad == 0:
        return x, L
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), L


@functools.partial(
    jax.jit,
    static_argnames=("mode", "prompt_len", "block_size", "window", "scale",
                     "softcap", "block_q", "block_k", "interpret", "config"))
def flash_block_attention(q, k, v, *, mode: str = "block_causal",
                          prompt_len: int = 0, block_size: int = 1,
                          window: Optional[int] = None, scale: float = 1.0,
                          softcap: Optional[float] = None,
                          block_q: Optional[int] = None,
                          block_k: Optional[int] = None,
                          interpret: Optional[bool] = None,
                          config: Optional[tuning.KernelConfig] = None):
    """q: (b, L, Kv, G, hd); k/v: (b, L, Kv, hd) -> (b, L, Kv, G, hd) fp32.

    Self-attention over a full sequence (training / prefill). Padding rows
    added to reach the tile grid are hidden from real queries by extending
    the block-causal/causal structure (padded positions live strictly in
    the future); for ``bidirectional`` the wrapper masks them by assigning
    padded keys to a never-visible trailing CDLM block.
    """
    b, L, Kv, G, hd = q.shape
    cfg = tuning.resolve(
        "block_attn",
        config=tuning.merge_legacy(config, block_q=block_q, block_k=block_k,
                                   interpret=interpret),
        L=L)
    block_q, block_k, interpret = cfg.block_q, cfg.block_k, cfg.interpret
    # pad sequence to tile grid
    qp, _ = _pad_to(q, 1, block_q)
    kp, _ = _pad_to(k, 1, block_k)
    vp, _ = _pad_to(v, 1, block_k)
    Lp = qp.shape[1]
    Lkp = kp.shape[1]

    eff_mode = mode
    if mode == "bidirectional" and Lkp != L:
        # treat padding as a trailing block under block_causal with a huge
        # block: real positions form block 0, padded keys block >= 1
        eff_mode = "block_causal"
        prompt_len = 0
        block_size = L

    # flatten (b, Kv, G) -> bh for q; KV heads stay unexpanded — the kernel
    # maps query head h to KV head h // G in its BlockSpec index map, so the
    # G-fold KV repeat never lands in HBM
    qf = qp.transpose(0, 2, 3, 1, 4).reshape(b * Kv * G, Lp, hd)
    kf = kp.transpose(0, 2, 1, 3).reshape(b * Kv, Lkp, hd)
    vf = vp.transpose(0, 2, 1, 3).reshape(b * Kv, Lkp, hd)

    out = block_attention(qf, kf, vf, mode=eff_mode, prompt_len=prompt_len,
                          block_size=block_size, window=window, scale=scale,
                          softcap=softcap, block_q=block_q, block_k=block_k,
                          g=G, interpret=interpret)
    out = out.reshape(b, Kv, G, Lp, hd).transpose(0, 3, 1, 2, 4)
    return out[:, :L]
