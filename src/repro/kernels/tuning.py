"""Kernel autotuning: one :class:`KernelConfig` across every kernel op,
plus a persisted registry of swept-best configs per
``(op, shape-bucket, backend)``.

The four kernel packages used to expose their tile/grid knobs as scattered
per-op kwargs (``block_t``/``block_v`` on select and xent, ``block_k`` on
decode_attn, ``block_q``/``block_k`` on block_attn, the streaming-fallback
vocab ``chunk``, plus ``impl``/``interpret``). :class:`KernelConfig` is the
union of those knobs as a single frozen (hashable, jit-static) dataclass
consumed by every op's ``config=`` parameter; the legacy kwargs keep working
as deprecated pass-throughs and take precedence when given explicitly.

When a caller passes *neither* an explicit kwarg nor a config field, the op
resolves the knob from the **tuned-config table**
(``src/repro/kernels/tuned_configs.json``, checked in): best configs found
by :func:`run_sweep` (driven by ``benchmarks/bench_kernels.py --tune``),
keyed by op name, a coarse power-of-two shape bucket, and the jax backend.
Unknown ``(op, bucket, backend)`` combinations fall back cleanly to the
op's built-in defaults, so the table is an accelerator, never a
correctness dependency.

Resolution precedence (per knob):

  explicit legacy kwarg  >  ``config=`` field  >  tuned table  >  built-in

Sweeps time the *jit-compiled* path of each op on the current backend: on
CPU that is the streaming/scan fallbacks (select's vocab-chunked scan,
xent's chunked backward) — timing the interpreted Pallas kernels would
measure the interpreter, so Pallas tile sweeps only run on compiled
backends (TPU/GPU).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax

TABLE_PATH = os.path.join(os.path.dirname(__file__), "tuned_configs.json")

OPS = ("select", "xent", "decode_attn", "block_attn")


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """The union of every kernel op's tuning knobs.

    ``None`` fields mean "not specified": resolution falls through to the
    tuned table, then the op's built-in default. Frozen (hashable) so a
    config can ride through ``jax.jit`` as a static argument.

    - ``block_t``  — row tile: decode rows (select) / tokens (xent);
    - ``block_v``  — vocab tile of the Pallas select/xent kernels;
    - ``block_q``  — query tile (block_attn);
    - ``block_k``  — key tile (block_attn) / cache tile (decode_attn);
    - ``chunk``    — vocab chunk of the jit'd streaming fallbacks
                     (select's scan impl, xent's chunked backward);
    - ``impl``     — select implementation ("auto" | "pallas" | "streaming");
    - ``interpret``— force Pallas interpret mode (None = backend default).
    """
    block_t: Optional[int] = None
    block_v: Optional[int] = None
    block_q: Optional[int] = None
    block_k: Optional[int] = None
    chunk: Optional[int] = None
    impl: Optional[str] = None
    interpret: Optional[bool] = None

    def to_dict(self) -> Dict[str, Any]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "KernelConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown KernelConfig fields {sorted(unknown)}")
        return cls(**d)


#: Built-in defaults per op — the historical kwarg defaults, so an empty
#: or unknown table reproduces pre-tuning behavior exactly.
OP_DEFAULTS: Dict[str, KernelConfig] = {
    "select": KernelConfig(block_t=128, block_v=512, impl="auto"),
    "xent": KernelConfig(block_t=128, block_v=512),
    "decode_attn": KernelConfig(block_k=128),
    "block_attn": KernelConfig(block_q=128, block_k=128),
}


def pow2_bucket(n: int) -> int:
    """Round ``n`` up to the next power of two (the bucket granularity)."""
    if n <= 1:
        return 1
    return 1 << (int(n) - 1).bit_length()


def bucket_for(op: str, **shape) -> str:
    """Coarse shape-bucket label per op.

    select/xent bucket on the vocab (``V``) — the axis the kernels tile and
    the one that dominates cost; decode_attn on the cache length (``S``);
    block_attn on the sequence length (``L``). Buckets are next-pow2, so
    V=32_768 and V=131_072 land in distinct buckets while e.g. 50k-ish
    tokenizer vocabs share one.
    """
    if op in ("select", "xent"):
        return f"V{pow2_bucket(shape['V'])}"
    if op == "decode_attn":
        return f"S{pow2_bucket(shape['S'])}"
    if op == "block_attn":
        return f"L{pow2_bucket(shape['L'])}"
    raise ValueError(f"unknown op {op!r} (expected one of {OPS})")


def backend() -> str:
    return jax.default_backend()


# ---------------------------------------------------------------------------
# Registry (load / lookup / resolve / save)
# ---------------------------------------------------------------------------
_TABLE_CACHE: Dict[str, Dict[Tuple[str, str, str], Dict[str, Any]]] = {}


def _load(path: Optional[str] = None) -> Dict[Tuple[str, str, str],
                                              Dict[str, Any]]:
    path = path or TABLE_PATH
    if path in _TABLE_CACHE:
        return _TABLE_CACHE[path]
    entries: Dict[Tuple[str, str, str], Dict[str, Any]] = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
        for e in data.get("entries", []):
            entries[(e["op"], e["bucket"], e["backend"])] = e
    _TABLE_CACHE[path] = entries
    return entries


def clear_cache() -> None:
    """Drop the in-process table cache (tests / after re-sweeping)."""
    _TABLE_CACHE.clear()


def lookup(op: str, bucket: str, *, backend_name: Optional[str] = None,
           path: Optional[str] = None) -> Optional[KernelConfig]:
    """Best known config for ``(op, bucket, backend)``; ``None`` when the
    table has no entry (callers then use built-in defaults)."""
    entry = _load(path).get((op, bucket, backend_name or backend()))
    if entry is None:
        return None
    return KernelConfig.from_dict(entry["config"])


def resolve(op: str, *, config: Optional[KernelConfig] = None,
            table_path: Optional[str] = None, **shape) -> KernelConfig:
    """Fully-resolved config for one op call.

    ``config`` fields that are set win over the tuned table; the table wins
    over :data:`OP_DEFAULTS`; every knob ends up non-None iff the op's
    default sets it. Explicit legacy kwargs are merged by the op *before*
    calling this (they are folded into ``config``).
    """
    if op not in OP_DEFAULTS:
        raise ValueError(f"unknown op {op!r} (expected one of {OPS})")
    layers = [OP_DEFAULTS[op]]
    tuned = lookup(op, bucket_for(op, **shape), path=table_path)
    if tuned is not None:
        layers.append(tuned)
    if config is not None:
        layers.append(config)
    merged: Dict[str, Any] = {}
    for layer in layers:
        for k, v in layer.to_dict().items():
            merged[k] = v
    return KernelConfig(**merged)


def merge_legacy(config: Optional[KernelConfig],
                 **legacy) -> Optional[KernelConfig]:
    """Fold explicitly-passed legacy kwargs (non-None values) over
    ``config`` — the deprecated pass-through path. Returns ``None`` when
    nothing was specified at all (pure table/default resolution)."""
    explicit = {k: v for k, v in legacy.items() if v is not None}
    if not explicit:
        return config
    base = config.to_dict() if config is not None else {}
    base.update(explicit)
    return KernelConfig(**base)


def save_table(entries: List[Dict[str, Any]],
               path: Optional[str] = None) -> str:
    """Write a sweep's best-config entries, replacing same-key rows of any
    existing table (other backends' rows are preserved)."""
    path = path or TABLE_PATH
    merged = dict(_load(path)) if os.path.exists(path) else {}
    _TABLE_CACHE.pop(path, None)
    for e in entries:
        merged[(e["op"], e["bucket"], e["backend"])] = e
    rows = sorted(merged.values(),
                  key=lambda e: (e["op"], e["bucket"], e["backend"]))
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": rows}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    return path


# ---------------------------------------------------------------------------
# Sweeps
# ---------------------------------------------------------------------------
def _time_us(fn, *args, iters: int = 5, repeats: int = 3) -> float:
    """Best-of-``repeats`` average over ``iters`` calls. Min-of-windows
    rejects OS scheduler noise a single average folds in — without it a
    loaded host can invert sweep rankings."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def _entry(op: str, bucket: str, cfg: KernelConfig, us: float,
           baseline_us: float, shape: Dict[str, int]) -> Dict[str, Any]:
    return {"op": op, "bucket": bucket, "backend": backend(),
            "config": cfg.to_dict(), "metric": "us_per_call",
            "value": round(us, 1), "baseline_us": round(baseline_us, 1),
            "shape": shape}


def select_candidates() -> List[KernelConfig]:
    """Sweep space for the fused-select op on the current backend."""
    if backend() == "tpu":
        return [KernelConfig(impl="pallas", block_t=bt, block_v=bv)
                for bt in (64, 128, 256) for bv in (512, 1024, 2048)]
    # CPU/GPU fast path is the jit'd vocab-chunked streaming scan
    return [KernelConfig(impl="streaming", chunk=c)
            for c in (512, 1024, 2048, 4096, 8192, 16384)]


def sweep_select(*, T: int = 32, d: int = 128,
                 vocabs: Tuple[int, ...] = (32_768, 131_072),
                 iters: int = 3, verbose: bool = True) -> List[Dict[str, Any]]:
    """Per-vocab-bucket sweep of the fused-select op vs its dense baseline.

    Times the jit-compiled path (streaming scan on CPU/GPU, the Pallas
    kernel on TPU) at decode-step shapes; returns registry entries for the
    best config per bucket.
    """
    import jax.numpy as jnp

    from repro.kernels.select import fused_select, select_ref

    key = jax.random.PRNGKey(0)
    entries = []
    for V in vocabs:
        ks = jax.random.split(key, 3)
        h = jax.random.normal(ks[0], (T, d), jnp.float32) * 0.5
        w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
        m = jax.random.bernoulli(ks[2], 0.7, (T,))
        base = jax.jit(select_ref, static_argnames=("softcap",))
        tb = _time_us(base, h, w, m, iters=iters)
        best: Tuple[float, Optional[KernelConfig]] = (float("inf"), None)
        for cfg in select_candidates():
            fn = jax.jit(lambda h, w, m, cfg=cfg: fused_select(
                h, w, m, config=cfg))
            tf = _time_us(fn, h, w, m, iters=iters)
            if verbose:
                print(f"  select V={V} {cfg.to_dict()}: {tf:9.0f}us "
                      f"({tb / tf:.2f}x baseline)")
            if tf < best[0]:
                best = (tf, cfg)
        bucket = bucket_for("select", V=V)
        entries.append(_entry("select", bucket, best[1], best[0], tb,
                              {"T": T, "d": d, "V": V}))
        if verbose:
            print(f"  select {bucket}: best {best[1].to_dict()} "
                  f"{best[0]:9.0f}us ({tb / best[0]:.2f}x baseline)")
    return entries


def sweep_xent(*, T: int = 64, d: int = 128,
               vocabs: Tuple[int, ...] = (32_768,), iters: int = 3,
               verbose: bool = True) -> List[Dict[str, Any]]:
    """Sweep the fused-xent backward's vocab chunk (the jit'd scan path —
    CPU-timeable) or, on TPU, the forward kernel's tiles."""
    import jax.numpy as jnp

    from repro.kernels.xent import fused_xent

    key = jax.random.PRNGKey(1)
    entries = []
    on_tpu = backend() == "tpu"
    for V in vocabs:
        ks = jax.random.split(key, 3)
        h = jax.random.normal(ks[0], (T, d), jnp.float32) * 0.5
        w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
        y = jax.random.randint(ks[2], (T,), 0, V)
        if on_tpu:
            cands = [KernelConfig(block_t=bt, block_v=bv)
                     for bt in (64, 128, 256) for bv in (512, 1024, 2048)]

            def run(cfg):
                return jax.jit(lambda h, w, y, cfg=cfg: fused_xent(
                    h, w, y, config=cfg))
        else:
            cands = [KernelConfig(chunk=c)
                     for c in (512, 1024, 2048, 4096, 8192)]

            def run(cfg):
                # the backward is the jit'd scan whose chunk we tune; the
                # interpreted forward is excluded from both sides equally
                # by timing grad-of-sum through the same forward config
                return jax.jit(jax.grad(
                    lambda h, w, y, cfg=cfg: fused_xent(
                        h, w, y, config=cfg).sum(), argnums=(0, 1)),
                    static_argnames=())
        baseline_cfg = cands[0]
        tb = _time_us(run(baseline_cfg), h, w, y, iters=iters)
        best: Tuple[float, Optional[KernelConfig]] = (tb, baseline_cfg)
        for cfg in cands[1:]:
            tf = _time_us(run(cfg), h, w, y, iters=iters)
            if verbose:
                print(f"  xent V={V} {cfg.to_dict()}: {tf:9.0f}us")
            if tf < best[0]:
                best = (tf, cfg)
        bucket = bucket_for("xent", V=V)
        entries.append(_entry("xent", bucket, best[1], best[0], tb,
                              {"T": T, "d": d, "V": V}))
        if verbose:
            print(f"  xent {bucket}: best {best[1].to_dict()} "
                  f"{best[0]:9.0f}us")
    return entries


def sweep_decode_attn(*, b: int = 4, Bq: int = 8, Kv: int = 2, hd: int = 64,
                      S: int = 1024, iters: int = 3,
                      verbose: bool = True) -> List[Dict[str, Any]]:
    """Cache-tile (``block_k``) sweep of the dense decode-attention kernel.
    Compiled backends only — the interpreted kernel's timing reflects the
    Pallas interpreter, not HBM behavior."""
    if backend() not in ("tpu", "gpu"):
        if verbose:
            print("  decode_attn: skipped (Pallas kernel is interpreted on "
                  f"{backend()}; tile timings would measure the interpreter)")
        return []
    import jax.numpy as jnp

    from repro.kernels.decode_attn import decode_attention

    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, Bq, Kv, 2, hd))
    kc = jax.random.normal(ks[1], (b, S, Kv, hd))
    vc = jax.random.normal(ks[2], (b, S, Kv, hd))
    kb = jax.random.normal(ks[3], (b, Bq, Kv, hd))
    vb = jax.random.normal(ks[4], (b, Bq, Kv, hd))
    clen = jnp.asarray(S, jnp.int32)
    entries = []
    best: Tuple[float, Optional[KernelConfig]] = (float("inf"), None)
    tb = None
    for bk in (64, 128, 256, 512):
        if S % bk:
            continue
        cfg = KernelConfig(block_k=bk)
        fn = jax.jit(lambda q, kc, vc, kb, vb, c, cfg=cfg: decode_attention(
            q, kc, vc, kb, vb, c, scale=0.125, config=cfg))
        tf = _time_us(fn, q, kc, vc, kb, vb, clen, iters=iters)
        tb = tf if tb is None else tb
        if verbose:
            print(f"  decode_attn S={S} block_k={bk}: {tf:9.0f}us")
        if tf < best[0]:
            best = (tf, cfg)
    entries.append(_entry("decode_attn", bucket_for("decode_attn", S=S),
                          best[1], best[0], tb,
                          {"b": b, "Bq": Bq, "Kv": Kv, "hd": hd, "S": S}))
    return entries


def sweep_block_attn(*, b: int = 1, L: int = 1024, Kv: int = 2, G: int = 2,
                     hd: int = 64, iters: int = 3,
                     verbose: bool = True) -> List[Dict[str, Any]]:
    """Tile sweep (``block_q``/``block_k``) of the block-causal flash
    kernel. Compiled backends only (see :func:`sweep_decode_attn`)."""
    if backend() not in ("tpu", "gpu"):
        if verbose:
            print("  block_attn: skipped (Pallas kernel is interpreted on "
                  f"{backend()})")
        return []
    import jax.numpy as jnp  # noqa: F401

    from repro.kernels.block_attn import flash_block_attention

    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, L, Kv, G, hd))
    k = jax.random.normal(ks[1], (b, L, Kv, hd))
    v = jax.random.normal(ks[2], (b, L, Kv, hd))
    entries = []
    best: Tuple[float, Optional[KernelConfig]] = (float("inf"), None)
    tb = None
    for bq in (128, 256):
        for bk in (128, 256, 512):
            cfg = KernelConfig(block_q=bq, block_k=bk)
            fn = jax.jit(lambda q, k, v, cfg=cfg: flash_block_attention(
                q, k, v, mode="block_causal", prompt_len=64, block_size=32,
                scale=0.125, config=cfg))
            tf = _time_us(fn, q, k, v, iters=iters)
            tb = tf if tb is None else tb
            if verbose:
                print(f"  block_attn L={L} bq={bq} bk={bk}: {tf:9.0f}us")
            if tf < best[0]:
                best = (tf, cfg)
    entries.append(_entry("block_attn", bucket_for("block_attn", L=L),
                          best[1], best[0], tb,
                          {"b": b, "L": L, "Kv": Kv, "G": G, "hd": hd}))
    return entries


def run_sweep(ops: Optional[Tuple[str, ...]] = None, *,
              vocabs: Tuple[int, ...] = (32_768, 131_072),
              iters: int = 3, out_path: Optional[str] = None,
              verbose: bool = True) -> List[Dict[str, Any]]:
    """Sweep the requested ops on the current backend and persist the best
    configs. Default op set: everything timeable on this backend."""
    ops = ops or OPS
    entries: List[Dict[str, Any]] = []
    if "select" in ops:
        entries += sweep_select(vocabs=vocabs, iters=iters, verbose=verbose)
    if "xent" in ops:
        entries += sweep_xent(vocabs=vocabs[:1], iters=iters, verbose=verbose)
    if "decode_attn" in ops:
        entries += sweep_decode_attn(iters=iters, verbose=verbose)
    if "block_attn" in ops:
        entries += sweep_block_attn(iters=iters, verbose=verbose)
    if entries:
        path = save_table(entries, out_path)
        if verbose:
            print(f"wrote {len(entries)} tuned configs -> {path}")
    return entries
