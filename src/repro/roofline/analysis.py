"""Three-term roofline from a compiled dry-run artifact (brief §Roofline).

XLA's ``cost_analysis``/``memory_analysis`` on an SPMD-partitioned module
report PER-DEVICE numbers (verified empirically: a 16-way sharded matmul
reports flops/16 and the shard's argument bytes), so:

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = collective_wire_bytes_per_chip / link_bw

(equivalent to the brief's global-FLOPs ÷ (chips × peak) formulation),
plus MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the useful-
compute ratio MODEL_FLOPS / (chips × HLO_FLOPs_per_chip)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.configs.base import TPU_V5E, HardwareConfig, ModelConfig
from repro.roofline.hlo import collective_bytes


def model_flops(cfg: ModelConfig, tokens: int, kind: str) -> float:
    """6·N·D for training; 2·N·D for inference steps (fwd only)."""
    n = cfg.active_param_count()
    mult = 6.0 if kind.startswith("train") else 2.0
    return mult * n * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_detail: Dict[str, Any]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    useful_ratio: float
    bottleneck: str
    per_device_mem: Optional[float]

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, lowered, *, cfg: ModelConfig, shape_name: str,
            mesh_name: str, chips: int, tokens: int, kind: str,
            hw: HardwareConfig = TPU_V5E) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text()
    coll = collective_bytes(text)

    # per-device numbers (see module docstring)
    compute_s = flops / hw.peak_flops
    memory_s = nbytes / hw.hbm_bw
    collective_s = coll["wire_bytes"] / hw.ici_bw
    mf = model_flops(cfg, tokens, kind)

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = float(getattr(ma, "temp_size_in_bytes", 0)
                        + getattr(ma, "argument_size_in_bytes", 0)
                        + getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass

    return RooflineReport(
        arch=cfg.name, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, coll_bytes=coll["total_bytes"],
        coll_detail=coll, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, model_flops=mf,
        useful_ratio=mf / (flops * chips) if flops else 0.0,
        bottleneck=bottleneck, per_device_mem=mem)
