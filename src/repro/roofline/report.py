"""Render the §Dry-run / §Roofline markdown tables from the dry-run JSONs.

    PYTHONPATH=src python -m repro.roofline.report
"""
from __future__ import annotations

import json
import os
from typing import List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def _fmt_b(x: float) -> str:
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(path: str) -> List[dict]:
    return json.load(open(path))


def roofline_table(recs: List[dict]) -> str:
    lines = [
        "| arch | shape | FLOPs/chip | B/chip | coll B/chip | compute | "
        "memory | collective | bound | useful | mem/chip |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    recs = sorted([r for r in recs if r.get("status") == "ok"],
                  key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    for r in recs:
        mem = r.get("memory_analysis") or {}
        tot = (mem.get("temp_bytes", 0) + mem.get("argument_bytes", 0))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['hlo_flops']:.2e} | "
            f"{r['hlo_bytes']:.2e} | {r['coll_bytes']:.2e} | "
            f"{_fmt_s(r['compute_s'])} | {_fmt_s(r['memory_s'])} | "
            f"{_fmt_s(r['collective_s'])} | **{r['bottleneck']}** | "
            f"{r['useful_ratio']:.2f} | {_fmt_b(tot)} |")
    for r in [x for x in recs if x.get("status") == "skipped"]:
        lines.append(f"| {r['arch']} | {r['shape']} | skipped | | | | | | | | |")
    return "\n".join(lines)


def dryrun_table(recs: List[dict]) -> str:
    lines = [
        "| arch | shape | status | lower | compile | collective schedule |",
        "|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         SHAPE_ORDER.index(r["shape"]))):
        if r.get("status") == "ok":
            cs = r["coll_detail"]["counts"]
            sched = " ".join(f"{k}×{v}" for k, v in sorted(cs.items()))
            lines.append(f"| {r['arch']} | {r['shape']} | ok | "
                         f"{r['lower_s']:.0f}s | {r['compile_s']:.0f}s | "
                         f"{sched} |")
        elif r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | | | "
                         f"{r['reason'][:80]} |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | "
                         f"{r.get('error', '')[:80]} |")
    return "\n".join(lines)


def main():
    base = os.path.join("experiments", "dryrun")
    single = load(os.path.join(base, "dryrun.json"))
    print("## Single-pod (16×16 = 256 chips) roofline\n")
    print(roofline_table(single))
    mp_path = os.path.join(base, "dryrun_multipod.json")
    if os.path.exists(mp_path):
        multi = load(mp_path)
        print("\n\n## Multi-pod (2×16×16 = 512 chips) dry-run\n")
        print(dryrun_table(multi))


if __name__ == "__main__":
    main()
