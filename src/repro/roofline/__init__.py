from repro.roofline.analysis import RooflineReport, analyze, model_flops  # noqa: F401
from repro.roofline.hlo import collective_bytes  # noqa: F401
