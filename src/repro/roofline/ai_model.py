"""Analytic arithmetic-intensity / roofline model — paper §5.4, Fig. 4,
App. B.4 reproduction.

Per-decoding-step FLOPs and HBM traffic for three inference regimes:

- AR:         1 token/step, weights + KV-cache traffic dominate -> AI ~ 1
              at bs=1, scaling ~linearly with batch until KV traffic binds.
- vanilla DLM: recomputes the full (L_p + L_g) sequence with bidirectional
              attention every step, no cache -> compute-bound at bs=1.
- block-wise DLM (CDLM): B tokens/step against cached prefix -> AI ~ B at
              bs=1, crossing the ridge at small batch.

Once the KV cache lands, the block-wise step's residual HBM hog is the
dense lm_head's (T, V) logits round-trip; ``fused_select=True`` accounts
the fused unembed + online-softmax selection kernel
(``repro.kernels.select``) instead — same unembed FLOPs and weight read,
but only per-token (id, confidence) traffic on the activation side. The
paper-target columns below keep the dense default.

The accounting follows the paper's references (Tiwari et al. 2025; Kim et
al. 2025): matmul FLOPs = 2·m·n·k; every GEMM reads A and W and writes C;
attention reads/writes scores and the KV stream; norm/activation traffic is
counted as reads+writes of the hidden state. Paper targets (A100, LLaMA-3.1
-8B AR / LLaDA-8B DLM, L_p=512, L_g=256): AR bs=1 AI≈1.0, bs∈{2,4,8} ->
{2.0, 4.0, 7.8}; vanilla bs=1 AI≈438.9; block-wise bs=1 AI≈{4.0, 15.8,
31.1} for B∈{4,16,32}; ridge 153.0.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import A100, HardwareConfig


@dataclasses.dataclass(frozen=True)
class AIModelConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    dtype_bytes: int = 2
    gated_ffn: bool = True


LLAMA31_8B = AIModelConfig(n_layers=32, d_model=4096, n_heads=32,
                           n_kv_heads=8, d_ff=14336, vocab=128_256)
LLADA_8B = AIModelConfig(n_layers=32, d_model=4096, n_heads=32,
                         n_kv_heads=32, d_ff=12288, vocab=126_464)


def param_bytes(m: AIModelConfig) -> float:
    d, hd = m.d_model, m.d_model // m.n_heads
    per_layer = (d * m.n_heads * hd + 2 * d * m.n_kv_heads * hd
                 + m.n_heads * hd * d)
    per_layer += (3 if m.gated_ffn else 2) * d * m.d_ff
    n = m.n_layers * per_layer + 2 * m.vocab * d
    return n * m.dtype_bytes


def step_cost(m: AIModelConfig, *, q_tokens: int, ctx_tokens: int,
              batch: int, causal_frac: float = 1.0,
              kv_cached: bool = True,
              fused_select: bool = False) -> Dict[str, float]:
    """FLOPs + HBM bytes for one decoding step processing ``q_tokens`` new
    positions against ``ctx_tokens`` of context per sequence.

    kv_cached=False (vanilla DLM) recomputes K/V for the whole context
    instead of streaming it from cache (the cost is then inside q_tokens =
    ctx_tokens and ctx reads count activation traffic, not cache).

    fused_select=True models the fused unembed + online-softmax selection
    kernel (``repro.kernels.select``): decode arithmetic intensity then
    counts the fused selection instead of a dense lm_head — the unembed
    FLOPs and weight read are unchanged, but the ``T × V`` logits tensor is
    never written to (or re-read from) HBM; only per-token (candidate id,
    confidence) pairs come back. At V ≳ 100k this removes the largest
    activation of the cached block-wise step and pushes its AI well past
    the dense-lm_head figure (paper Fig. 4 baselines keep the default)."""
    d, hd = m.d_model, m.d_model // m.n_heads
    nq, nkv = m.n_heads, m.n_kv_heads
    B = m.dtype_bytes
    T = q_tokens * batch

    flops = 0.0
    bytes_ = 0.0

    # --- weights are read once per step (batch-amortized) ---
    bytes_ += param_bytes(m)

    per_tok_mm_flops = 0.0
    per_tok_act_bytes = 0.0

    # attention projections
    qkv_out = nq * hd + 2 * nkv * hd
    per_tok_mm_flops += 2 * d * qkv_out + 2 * (nq * hd) * d
    per_tok_act_bytes += (d + qkv_out + nq * hd + d) * B
    # FFN
    ff_mats = 3 if m.gated_ffn else 2
    per_tok_mm_flops += ff_mats * 2 * d * m.d_ff
    per_tok_act_bytes += (d + ff_mats * m.d_ff + d) * B
    # norms + residuals (reads + writes of hidden state, ~6 passes)
    per_tok_act_bytes += 6 * d * B

    flops += m.n_layers * per_tok_mm_flops * T
    bytes_ += m.n_layers * per_tok_act_bytes * T

    # attention score/value math: q_tokens × ctx_tokens
    attn_ctx = ctx_tokens * causal_frac
    flops += m.n_layers * batch * (2 * q_tokens * attn_ctx * nq * hd) * 2
    # scores traffic (write + read of p), fp16
    bytes_ += m.n_layers * batch * (q_tokens * attn_ctx * nq) * B * 2

    # KV stream
    kv_bytes_per_tok = 2 * nkv * hd * B
    if kv_cached:
        bytes_ += m.n_layers * batch * ctx_tokens * kv_bytes_per_tok  # read
        bytes_ += m.n_layers * batch * q_tokens * kv_bytes_per_tok    # write
    # (vanilla recompute: K/V activations already counted above)

    # lm head on the q tokens: W is read either way; the dense path also
    # round-trips (T, V) logits through HBM, the fused select kernel emits
    # only an int32 candidate + fp32 confidence per token
    flops += 2 * d * m.vocab * T
    bytes_ += (m.vocab * d) * B
    bytes_ += T * 8 if fused_select else T * m.vocab * B

    return {"flops": flops, "bytes": bytes_, "ai": flops / bytes_}


def ar_ai(m: AIModelConfig, batch: int, L_p=512, L_g=256) -> float:
    ctx = L_p + L_g // 2  # average context during generation
    return step_cost(m, q_tokens=1, ctx_tokens=ctx, batch=batch,
                     causal_frac=1.0, kv_cached=True)["ai"]


def vanilla_dlm_ai(m: AIModelConfig, batch: int, L_p=512, L_g=256) -> float:
    L = L_p + L_g
    return step_cost(m, q_tokens=L, ctx_tokens=L, batch=batch,
                     causal_frac=1.0, kv_cached=False)["ai"]


def blockwise_dlm_ai(m: AIModelConfig, batch: int, block: int,
                     L_p=512, L_g=256, fused_select: bool = False) -> float:
    ctx = L_p + L_g // 2
    return step_cost(m, q_tokens=block, ctx_tokens=ctx, batch=batch,
                     causal_frac=1.0, kv_cached=True,
                     fused_select=fused_select)["ai"]


def attainable_tflops(ai: float, hw: HardwareConfig = A100) -> float:
    return min(hw.peak_flops, ai * hw.hbm_bw) / 1e12


PAPER_TARGETS = {
    ("ar", 1): 1.0, ("ar", 2): 2.0, ("ar", 4): 4.0, ("ar", 8): 7.8,
    ("ar", 128): 71.3,
    ("vanilla", 1): 438.9,
    ("block4", 1): 4.0, ("block16", 1): 15.8, ("block32", 1): 31.1,
}


def paper_table(batches=(1, 2, 4, 8, 16, 32, 64, 128)):
    """The Fig. 4 sweep with the paper's configurations."""
    rows = []
    for bs in batches:
        rows.append({
            "batch": bs,
            "ar": ar_ai(LLAMA31_8B, bs),
            "vanilla": vanilla_dlm_ai(LLADA_8B, bs),
            "block4": blockwise_dlm_ai(LLADA_8B, bs, 4),
            "block16": blockwise_dlm_ai(LLADA_8B, bs, 16),
            "block32": blockwise_dlm_ai(LLADA_8B, bs, 32),
        })
    return rows
