"""HLO-text analysis: collective-traffic extraction.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
bytes, so we parse the optimized HLO for ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` ops and sum
their *output* shape sizes (the standard per-chip traffic proxy; for
all-reduce the wire traffic is ~2× output with ring algorithms — reported
separately as ``wire_bytes``)."""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[2,1024,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str, top_n: int = 8) -> Dict[str, float]:
    """Sum output bytes per collective kind. '-done' ops are skipped so
    async pairs are not double-counted. Also returns the ``top_n`` largest
    individual collectives (kind, bytes, shape) — the hillclimb entry
    point."""
    out = defaultdict(float)
    count = defaultdict(int)
    ops = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        kind = m.group(4)
        if m.group(1) is not None:  # tuple shape
            nbytes = sum(_shape_bytes(dt, dims)
                         for dt, dims in _SHAPE_RE.findall(m.group(1)))
            shape = m.group(1)[:80]
        else:
            nbytes = _shape_bytes(m.group(2), m.group(3))
            shape = f"{m.group(2)}[{m.group(3)}]"
        out[kind] += nbytes
        count[kind] += 1
        ops.append((nbytes, kind, shape))
    ops.sort(reverse=True)
    total = sum(out.values())
    wire = total + out.get("all-reduce", 0.0)  # ring AR moves ~2x
    return {"per_kind": dict(out), "counts": dict(count),
            "total_bytes": total, "wire_bytes": wire,
            "top_ops": [{"bytes": b, "kind": k, "shape": s}
                        for b, k, s in ops[:top_n]]}
