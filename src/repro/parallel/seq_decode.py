"""Sequence-parallel distributed flash-decode (beyond-paper, DESIGN.md §4/§7).

For long-context decode (long_500k) the KV cache dominates memory: sharding
its *sequence* dim over the ``model`` axis gives each chip S/16 slots. The
attention softmax then spans shards; we compute per-shard unnormalized
partials (acc, m, l) locally and merge with one tiny ``psum``-style
collective over (b, Bq, heads, hd) — the TPU analogue of flash-decode
split-K, exact to numerics. This replaces XLA's default behavior for
seq-sharded caches (all-gathering the cache), turning a multi-GB all-gather
per step into a ~MB collective.

Plugs into ``models.transformer.forward(decode_attention_fn=...)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def _local_partial(q, kc, vc, *, first_pos, cache_len, scale, softcap,
                   window, g):
    """Partials over this shard's cache slice. q: (b, BqG, Kv, hd) replct.
    kc/vc: (b, S_loc, Kv, hd). Positions of local slots: first_pos + i."""
    S_loc = kc.shape[1]
    s = jnp.einsum("bqkh,bskh->bkqs", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = first_pos + jnp.arange(S_loc)
    valid = kpos < cache_len
    if window is not None:
        qpos = cache_len + jnp.arange(q.shape[1]) // g
        valid = valid[None, :] & (qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(valid[None, None], s, NEG_INF)
    else:
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0))
    p = jnp.where(jnp.isfinite(m), p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bkqs,bskh->bkqh", p, vc.astype(jnp.float32))
    return acc, m, l


def make_sharded_decode_attention(mesh: Mesh, *, batch_axis, axis: str = "model"):
    """Returns a ``decode_attention_fn(q, kc, vc, k_blk, v_blk, cache_len,
    scale=..., softcap=..., window=...)`` with kc/vc sequence-sharded over
    ``axis``. q layout (b, Bq, Kv, G, hd); caches (b, S, Kv, hd)."""

    def fn(q, kc, vc, k_blk, v_blk, cache_len, *, scale, softcap=None,
           window=None):
        b, Bq, Kv, G, hd = q.shape
        S = kc.shape[1]
        n_shards = mesh.shape[axis]
        S_loc = S // n_shards
        qf = q.transpose(0, 1, 3, 2, 4).reshape(b, Bq * G, Kv, hd)
        clen = jnp.asarray(cache_len, jnp.int32)

        def local(qf, kc, vc, clen):
            idx = jax.lax.axis_index(axis)
            acc, m, l = _local_partial(
                qf, kc, vc, first_pos=idx * S_loc, cache_len=clen[0],
                scale=scale, softcap=softcap, window=window, g=G)
            # merge partials across shards: 3 small collectives
            m_glob = jax.lax.pmax(m, axis)
            m_safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
            w = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            acc = jax.lax.psum(acc * w, axis)
            l = jax.lax.psum(l * w, axis)
            return acc, m_glob, l

        in_specs = (
            P(batch_axis, None, None, None),          # q replicated over model
            P(batch_axis, axis, None, None),          # cache seq-sharded
            P(batch_axis, axis, None, None),
            P(),                                      # cache_len
        )
        out_specs = (P(batch_axis, None, None, None),
                     P(batch_axis, None, None, None),
                     P(batch_axis, None, None, None))
        acc, m, l = shard_map(local, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)(
            qf, kc, vc, clen.reshape(1))

        # in-block part (tiny) + final merge, replicated math
        kb = k_blk.transpose(0, 2, 1, 3).reshape(b * Kv, Bq, hd)
        vb = v_blk.transpose(0, 2, 1, 3).reshape(b * Kv, Bq, hd)
        qb = qf.transpose(0, 2, 1, 3).reshape(b * Kv, Bq * G, hd)
        s = jnp.einsum("bqh,bkh->bqk", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        if window is not None:
            qpos = jnp.arange(Bq * G)[:, None] // G
            kpos = jnp.arange(Bq)[None, :]
            s = jnp.where(jnp.abs(qpos - kpos) < window, s, NEG_INF)
        mb = jnp.max(s, axis=-1, keepdims=True)
        pb = jnp.exp(s - mb)
        lb = jnp.sum(pb, axis=-1, keepdims=True)
        accb = jnp.einsum("bqk,bkh->bqh", pb, vb.astype(jnp.float32))
        accb = accb.reshape(b, Kv, Bq * G, hd)
        mb = mb.reshape(b, Kv, Bq * G, 1)
        lb = lb.reshape(b, Kv, Bq * G, 1)

        m_tot = jnp.maximum(m, mb)
        m_safe = jnp.where(jnp.isfinite(m_tot), m_tot, 0.0)
        w1 = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        w2 = jnp.where(jnp.isfinite(mb), jnp.exp(mb - m_safe), 0.0)
        out = (acc * w1 + accb * w2) / jnp.maximum(l * w1 + lb * w2, 1e-30)
        out = out.reshape(b, Kv, Bq, G, hd).transpose(0, 2, 1, 3, 4)
        return out.astype(q.dtype)

    return fn
