"""Sharding rules: param-path regex → logical dim assignment → PartitionSpec.

Policy (DESIGN.md §7):
- tensor parallelism over the ``model`` mesh axis: attention heads
  (via the fused head*hd projection dim), FFN hidden, vocab, MoE experts,
  Mamba/RWKV inner channels;
- FSDP over the ``data`` axis on the complementary matrix dim (ZeRO-3
  style — optimizer states inherit the same spec);
- the ``pod`` axis is a pure data axis (batch / FSDP outer).

Every rule degrades per-leaf: an axis is applied to a dim only when the dim
size is divisible by the mesh-axis extent (e.g. qwen2's 14 query heads or
whisper's odd 51865 vocab fall back to replication on that dim).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, per-dim logical axes, applied right-aligned to the trailing
# dims — leading stack dims (periods) are never sharded)
# logical axes: "tp" = model axis, "fsdp" = data(+pod) axis
RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # embeddings / head: the d dim is the lm-head CONTRACTION dim — FSDP-
    # sharding it over "data" collides with batch sharding and forces an
    # all-reduce of (b, L, V/chip) fp32 logits (EXPERIMENTS.md §Perf H1);
    # vocab over "model" shards the bulk, d stays replicated.
    (r"embed/tok$", ("tp", None)),
    (r"embed/head$", (None, "tp")),
    # attention / dense mlp: shard the NON-contraction dim over the fused
    # (model, data) axes — Megatron column/row parallel at 256-way. FSDP on
    # the contraction dim collided with batch sharding and forced XLA to
    # replicate activations + all-reduce over "data" (§Perf H1 iter 3); the
    # row-parallel all-reduce of (b, L, d) activations is the cheap, normal
    # TP collective.
    (r"(attn|cross)/w[qkv]$", (None, "tp_fsdp")),
    (r"(attn|cross)/wo$", ("tp_fsdp", None)),
    (r"(attn|cross)/b[qkv]$", ("tp",)),
    (r"mlp/wi(_gate|_up)?$", (None, "tp_fsdp")),
    (r"mlp/wo$", ("tp_fsdp", None)),
    # MoE: expert-parallel on the expert dim
    (r"moe/router$", ("fsdp", None)),
    (r"moe/wi(_gate|_up)$", ("tp", "fsdp", None)),
    (r"moe/wo$", ("tp", None, "fsdp")),
    (r"moe/shared/wi(_gate|_up)$", ("fsdp", "tp")),
    (r"moe/shared/wo$", ("tp", "fsdp")),
    # mamba
    (r"mamba/in_proj$", ("fsdp", "tp")),
    (r"mamba/conv_[wb]$", (None, "tp")),
    (r"mamba/x_proj$", ("tp", None)),
    (r"mamba/dt_proj_w$", (None, "tp")),
    (r"mamba/dt_proj_b$", ("tp",)),
    (r"mamba/A_log$", ("tp", None)),
    (r"mamba/D$", ("tp",)),
    (r"mamba/out_proj$", ("tp", "fsdp")),
    # rwkv6
    (r"rwkv_tm/w[rkvg]$", ("fsdp", "tp")),
    (r"rwkv_tm/wo$", ("tp", "fsdp")),
    (r"rwkv_tm/wa$", ("fsdp", None)),
    (r"rwkv_tm/wb$", (None, "tp")),
    (r"rwkv_cm/wk$", ("fsdp", "tp")),
    (r"rwkv_cm/wv$", ("tp", "fsdp")),
    (r"rwkv_cm/wr$", ("fsdp", "tp")),
    # everything else (norms, mus, scalars): replicated
)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def logical_to_mesh(mesh: Mesh, logical: Optional[str], *, fsdp: bool):
    """Map logical axis -> concrete mesh axis/axes (or None)."""
    if logical == "tp":
        return "model"
    if logical == "tp_fsdp":
        if not fsdp:
            return "model"
        return (("model", "pod", "data") if "pod" in mesh.axis_names
                else ("model", "data"))
    if logical == "fsdp":
        if not fsdp:
            return None
        return ("pod", "data") if "pod" in mesh.axis_names else "data"
    return None


def spec_for_leaf(path: str, shape: Tuple[int, ...], mesh: Mesh,
                  *, fsdp: bool) -> P:
    for pattern, dims in RULES:
        if re.search(pattern, path):
            n = len(dims)
            lead = len(shape) - n
            if lead < 0:
                break
            axes = [None] * lead
            for d, logical in enumerate(dims):
                concrete = logical_to_mesh(mesh, logical, fsdp=fsdp)
                size = _axis_size(mesh, concrete)
                if concrete is not None and shape[lead + d] % size == 0 and size > 1:
                    axes.append(concrete)
                else:
                    axes.append(None)
            return P(*axes)
    return P()  # replicate


def param_specs(params, mesh: Mesh, *, fsdp: bool = True):
    """PartitionSpec tree mirroring ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_leaf(_path_str(path), leaf.shape, mesh,
                                         fsdp=fsdp),
        params)


def param_shardings(params, mesh: Mesh, *, fsdp: bool = True):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_specs(params, mesh, fsdp=fsdp))


def batch_axes(mesh: Mesh, size: int):
    """Largest prefix of (pod, data) whose product divides ``size``."""
    axes = []
    prod = 1
    for name in ("pod", "data"):
        if name in mesh.axis_names and size % (prod * mesh.shape[name]) == 0:
            axes.append(name)
            prod *= mesh.shape[name]
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def cache_spec(mesh: Mesh, batch: int, *, n_kv: int, seq_shard: bool) -> P:
    """Spec for KV cache leaves (np, b, S, kv, hd)."""
    b_ax = batch_axes(mesh, batch)
    if seq_shard:
        return P(None, b_ax, "model", None, None)
    kv_ax = "model" if n_kv % mesh.shape["model"] == 0 else None
    return P(None, b_ax, None, kv_ax, None)
