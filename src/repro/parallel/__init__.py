from repro.parallel.seq_decode import make_sharded_decode_attention  # noqa: F401
from repro.parallel.sharding import (  # noqa: F401
    batch_axes,
    cache_spec,
    param_shardings,
    param_specs,
    spec_for_leaf,
)
