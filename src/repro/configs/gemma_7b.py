"""Gemma-7B [dense] — GeGLU, head_dim=256, 16 KV heads [arXiv:2403.08295]."""
from repro.configs.base import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24_576,
    vocab_size=256_000,
    activation="gelu",        # GeGLU
    layer_period=((ATTN, MLP),),
    embed_scale=True,
    tie_embeddings=True,
    long_context_window=8_192,
    mask_token_id=255_999,
    eos_token_id=1,
)
