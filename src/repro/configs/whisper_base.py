"""Whisper-base [audio] — encoder-decoder, conv/mel frontend STUB
[arXiv:2212.04356].

Per the brief, the mel-spectrogram + conv feature extractor is stubbed:
``input_specs()`` provides (batch, 1500, d_model) pre-computed frame
embeddings consumed by the bidirectional encoder; we implement the decoder
transformer (self-attn + cross-attn). CDLM applies to the decoder
(block-causal self-attention; encoder states are "prompt" and cached).
long_500k is SKIPPED for this arch (DESIGN.md §6): a 30 s / 1500-frame
encoder with a ~448-token decoder has no meaningful 524k-token decode state.
"""
from repro.configs.base import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,               # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    activation="gelu_plain",  # whisper MLP is non-gated GELU
    layer_period=((ATTN, MLP),),
    norm_type="layernorm",
    pos_embed="sinusoidal",
    is_encoder_decoder=True,
    n_encoder_layers=6,
    encoder_seq_len=1500,
    mask_token_id=51_864,
    eos_token_id=50_257,
)
