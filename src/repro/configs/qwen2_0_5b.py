"""Qwen2-0.5B [dense] — GQA, QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    activation="silu",
    layer_period=((ATTN, MLP),),
    tie_embeddings=True,
    # sliding-window decode variant enabling long_500k (DESIGN.md §6)
    long_context_window=8_192,
    mask_token_id=151_935,
    eos_token_id=151_645,
)
