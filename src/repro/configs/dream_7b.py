"""Dream-7B-Instruct — the paper's primary target DLM [arXiv:2508.15487].

Qwen2.5-7B-derived backbone adapted to masked diffusion. Included alongside
the assigned pool so the paper's own tables have a config; exercised through
the same dry-run/roofline machinery (not part of the 10 assigned archs).
"""
from repro.configs.base import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="dream-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    activation="silu",
    layer_period=((ATTN, MLP),),
    long_context_window=8_192,
    mask_token_id=151_666,
    eos_token_id=151_645,
)
