"""RWKV6-1.6B "Finch" [ssm] — attention-free, data-dependent decay
[arXiv:2404.05892].

CDLM applicability: NONE (strictly causal recurrent backbone — no
bidirectional teacher exists and decode is already O(1)/token). Implemented
as a causal LM; see DESIGN.md §5. long_500k is natural (constant state).
"""
from repro.configs.base import RWKV, RWKV_CM, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,               # 2048 / head_size 64
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65_536,
    activation="relu_sq",     # RWKV channel-mix uses squared ReLU
    layer_period=((RWKV, RWKV_CM),),
    rwkv_head_size=64,
    pos_embed="none",         # recurrence encodes position

    norm_type="layernorm",
    mask_token_id=65_535,
    eos_token_id=0,
)
