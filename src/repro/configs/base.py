"""Configuration system for the CDLM framework.

Everything is a frozen dataclass so configs hash/compare cleanly and can be
used as static args under jit. ``ModelConfig`` describes an architecture;
``CDLMConfig`` describes the paper's technique knobs; ``TrainConfig`` /
``ServeConfig`` / ``MeshConfig`` describe the run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Layer kinds used in the per-period layer program (see models/transformer.py)
# ---------------------------------------------------------------------------
ATTN = "attn"          # self attention (mode decided at call time)
ATTN_LOCAL = "attn_local"  # sliding-window self attention (gemma2 local)
MAMBA = "mamba"        # selective SSM block (jamba)
RWKV = "rwkv"          # RWKV6 time-mix block

MLP = "mlp"            # dense FFN
MOE = "moe"            # mixture-of-experts FFN
RWKV_CM = "rwkv_cm"    # RWKV6 channel-mix (token-shifted FFN)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned architecture."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio

    # Core dims
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # Attention flavor
    qkv_bias: bool = False           # qwen-style QKV bias
    rope_theta: float = 10_000.0
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None         # window for ATTN_LOCAL layers
    query_pre_attn_scalar: Optional[float] = None  # gemma2 scales by this not head_dim
    # Optional sliding-window *decode* variant enabling long_500k for dense
    # archs (DESIGN.md §6): caps the attended cache length at decode time.
    long_context_window: Optional[int] = None

    # FFN flavor
    activation: str = "silu"         # silu (SwiGLU) | gelu (GeGLU)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None   # expert hidden dim (defaults to d_ff)
    n_shared_experts: int = 0        # kimi/deepseek-style shared expert
    router_aux_weight: float = 0.01  # load-balance aux loss weight
    capacity_factor: float = 1.25

    # SSM (mamba, for jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # RWKV6
    rwkv_head_size: int = 64

    # Layer program: tuple of per-layer "slot" kinds with period
    # ``len(layer_period)``; layer i uses layer_period[i % len(layer_period)].
    # Each slot is (mixer_kind, ffn_kind).
    layer_period: Tuple[Tuple[str, str], ...] = ((ATTN, MLP),)

    # Positional encoding: rope | sinusoidal (whisper) | none (rwkv)
    pos_embed: str = "rope"

    # Norms / embeddings
    norm_eps: float = 1e-6
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma scales embeddings by sqrt(d_model)

    # Encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0         # fixed encoder length (1500 audio frames)

    # Modality frontend stubs (spec carve-out): number of prefix embedding
    # positions supplied pre-computed by input_specs().
    n_prefix_embeds: int = 0         # VLM patch embeddings prepended to text

    # Diffusion
    mask_token_id: int = 0           # set per-config (vocab_size - 1 usually)
    eos_token_id: int = 1

    # Numerics
    dtype: str = "bfloat16"          # activation/param dtype for dry-run

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and self.moe_d_ff is None:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.family in ("ssm",), (
            f"{self.name}: n_heads={self.n_heads} not a multiple of n_kv_heads={self.n_kv_heads}")
        assert self.n_layers % len(self.layer_period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of period "
            f"{len(self.layer_period)}")

    # ---- derived -----------------------------------------------------------
    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layer_period)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return all(mix in (MAMBA, RWKV) for mix, _ in self.layer_period)

    @property
    def supports_bidirectional(self) -> bool:
        """Can this backbone act as a bidirectional DLM teacher?"""
        return not any(mix in (MAMBA, RWKV) for mix, _ in self.layer_period)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, hd = self.d_model, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        glu = 3  # gated FFNs use 3 matrices
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # head
        per = {}
        per[ATTN] = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
        per[ATTN_LOCAL] = per[ATTN]
        exp = self.mamba_expand * d
        per[MAMBA] = (d * exp * 2 + exp * self.mamba_d_conv
                      + exp * (self.mamba_d_state * 2 + 1)  # B,C,dt proj (approx)
                      + exp * d)
        per[RWKV] = 4 * d * d + d * d  # r,k,v,g,o projections (approx)
        per[MLP] = glu * d * self.d_ff
        per[RWKV_CM] = 2 * d * self.d_ff + d * d
        if self.n_experts:
            per[MOE] = ((self.n_experts + self.n_shared_experts)
                        * glu * d * self.moe_d_ff + d * self.n_experts)
        for mix, ffn in self.layer_period:
            key = (mix, ffn)
            per.setdefault(key, per[mix] + per[ffn] + 2 * d)
        total += sum(per[(mix, ffn)] for mix, ffn in self.layer_period) * self.n_periods
        if self.is_encoder_decoder:
            # encoder layers + cross attention in decoder
            total += self.n_encoder_layers * (per[ATTN] + per[MLP] + 2 * d)
            total += self.n_layers * per[ATTN]  # cross-attn per decoder layer
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        full_moe = self.n_experts * 3 * d * self.moe_d_ff
        active_moe = (self.experts_per_token + self.n_shared_experts) * 3 * d * self.moe_d_ff
        n_moe_layers = sum(1 for _, f in self.layer_period if f == MOE) * self.n_periods
        return int(self.param_count() - n_moe_layers * (full_moe - active_moe))

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: ≤2 periods, d_model≤256, ≤4 experts."""
        period = self.layer_period
        small = dict(
            n_layers=len(period) * min(2, self.n_periods),
            d_model=256 if self.d_model >= 256 else self.d_model,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=64,
            d_ff=512,
            vocab_size=512,
            mask_token_id=511,
            eos_token_id=1,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.n_experts else 0,
            moe_d_ff=256 if self.n_experts else None,
            n_shared_experts=min(self.n_shared_experts, 1),
            sliding_window=64 if self.sliding_window else None,
            long_context_window=128 if self.long_context_window else None,
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq_len=16 if self.is_encoder_decoder else 0,
            n_prefix_embeds=8 if self.n_prefix_embeds else 0,
            query_pre_attn_scalar=(64.0 if self.query_pre_attn_scalar else None),
            dtype="float32",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class CDLMConfig:
    """The paper's technique knobs (§4, App. A)."""

    block_size: int = 32             # B
    gen_length: int = 256            # L_g
    prompt_length: int = 512
    # Loss weights (Table 5/6 defaults for Dream)
    w_distill: float = 1.0
    w_cons: float = 0.5
    w_dlm: float = 0.01
    # Inference
    conf_threshold: float = 0.9      # τ_conf
    early_stop: bool = True
    # Trajectory collection (Alg. 1)
    temperatures: Tuple[float, ...] = (0.0, 0.5)
    # Distillation uses forward KL in logit space (App. A.2 findings)
    kl_direction: str = "forward"

    @property
    def n_blocks(self) -> int:
        return self.gen_length // self.block_size


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 2e-5
    warmup_frac: float = 0.05
    lr_schedule: str = "constant"   # constant | cosine
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    batch_size: int = 64
    steps: int = 1000
    seed: int = 0
    use_lora: bool = False
    lora_rank: int = 32
    lora_alpha: float = 32.0
    remat: bool = True               # checkpoint each layer period


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    block_size: int = 32
    gen_length: int = 256
    # conf_threshold / temperature are the *engine defaults*: every request
    # may override them per-request via repro.serving.SamplingParams (unset
    # fields inherit these values). One continuous batch can mix greedy and
    # sampled lanes — per-lane RNG streams keep each lane bit-identical to
    # its isolated decode.
    conf_threshold: float = 0.9
    temperature: float = 0.0
    sampler: str = "cdlm"            # vanilla|fast_dllm|dual_cache|interval_cache|cdlm|ar
    cache_refresh_interval: int = 8  # for interval_cache (dLLM-Cache analog)
    scheduler: str = "static"        # static | continuous (block-level batching)
    # KV memory layout (repro.core.cache.CACHE_LAYOUTS): "dense" preallocates
    # max_len rows per lane; "paged" backs KV with a global page pool
    # (page size = block_size) so lanes only consume memory they commit.
    cache_layout: str = "dense"
    # pool size in pages for the paged layout; None = dense-equivalent
    # capacity (max_batch lanes x full canvas). Smaller pools trade peak
    # concurrency for memory; the continuous scheduler admits by free pages.
    page_pool_pages: Optional[int] = None
    # Fused unembed + online-softmax candidate selection
    # (repro.kernels.select): decode forwards skip the lm_head and no
    # (b, ·, V) logits tensor is materialized. Greedy (temperature 0) only;
    # sampled decoding silently keeps the baseline logits path (in the
    # continuous engine: any step whose batch contains a sampled lane).
    fused_select: bool = False
    # HTTP frontend (repro.serving.server): bind address for the
    # OpenAI-style /v1/completions endpoint (launch.serve --http).
    http_host: str = "127.0.0.1"
    http_port: int = 8000


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# TPU v5e hardware constants for the roofline (per chip).
@dataclass(frozen=True)
class HardwareConfig:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9

    @property
    def ridge_ai(self) -> float:
        return self.peak_flops / self.hbm_bw


A100 = HardwareConfig(name="a100-sxm4-80g", peak_flops=311.9e12,
                      hbm_bw=2039e9, ici_bw=300e9, hbm_bytes=80e9)
TPU_V5E = HardwareConfig()
