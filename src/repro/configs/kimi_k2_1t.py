"""Kimi-K2-1T-A32B [moe] — trillion-param MoE, 384 experts top-8 + 1 shared,
small (2048) expert hidden dim [arXiv:2501.kimi2, paper table]."""
from repro.configs.base import ATTN, MOE, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,             # 7168 / 64
    d_ff=2048,
    vocab_size=163_840,
    rope_theta=50_000.0,
    activation="silu",
    n_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    layer_period=((ATTN, MOE),),   # 61 is prime -> period must be 1
    long_context_window=8_192,
    mask_token_id=163_839,
    eos_token_id=163_586,
)
