"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig

from repro.configs import (  # noqa: F401
    dream_7b,
    gemma2_27b,
    gemma_7b,
    internvl2_1b,
    jamba_v01_52b,
    kimi_k2_1t,
    llada_8b,
    llama4_maverick_400b,
    qwen1_5_110b,
    qwen2_0_5b,
    rwkv6_1_6b,
    whisper_base,
)

# The 10 assigned architectures (+ the paper's own two DLMs).
ARCHITECTURES: Dict[str, ModelConfig] = {
    "internvl2-1b": internvl2_1b.CONFIG,
    "llama4-maverick-400b-a17b": llama4_maverick_400b.CONFIG,
    "qwen2-0.5b": qwen2_0_5b.CONFIG,
    "rwkv6-1.6b": rwkv6_1_6b.CONFIG,
    "gemma-7b": gemma_7b.CONFIG,
    "jamba-v0.1-52b": jamba_v01_52b.CONFIG,
    "gemma2-27b": gemma2_27b.CONFIG,
    "kimi-k2-1t-a32b": kimi_k2_1t.CONFIG,
    "qwen1.5-110b": qwen1_5_110b.CONFIG,
    "whisper-base": whisper_base.CONFIG,
}

PAPER_ARCHITECTURES: Dict[str, ModelConfig] = {
    "dream-7b": dream_7b.CONFIG,
    "llada-8b": llada_8b.CONFIG,
}

ALL_ARCHITECTURES: Dict[str, ModelConfig] = {**ARCHITECTURES, **PAPER_ARCHITECTURES}

ASSIGNED_IDS = tuple(ARCHITECTURES.keys())


def get_config(arch: str) -> ModelConfig:
    try:
        return ALL_ARCHITECTURES[arch]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(ALL_ARCHITECTURES)}") from None
