"""InternVL2-1B [vlm] — InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821].

Per the brief, the vision encoder (InternViT-300M) + MLP projector are a STUB:
``input_specs()`` supplies 256 pre-computed patch embeddings of shape
(batch, 256, d_model) which the LM consumes as a prompt prefix. The config
below describes the transformer backbone that consumes them.
"""
from repro.configs.base import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    activation="silu",
    layer_period=((ATTN, MLP),),
    n_prefix_embeds=256,      # ViT patch tokens (stub frontend)
    long_context_window=8_192,
    mask_token_id=151_654,
    eos_token_id=151_645,
)
