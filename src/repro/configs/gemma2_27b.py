"""Gemma2-27B [dense] — alternating local(4096-window)/global attention,
attn & final logit softcaps [arXiv:2408.00118]."""
from repro.configs.base import ATTN, ATTN_LOCAL, MLP, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    activation="gelu",
    layer_period=((ATTN_LOCAL, MLP), (ATTN, MLP)),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_pre_attn_scalar=144.0,   # d_model / n_heads
    embed_scale=True,
    tie_embeddings=True,
    # long_500k: local layers are natively sub-quadratic; global layers use
    # the sequence-parallel sharded cache (DESIGN.md §6).
    long_context_window=None,
    mask_token_id=255_999,
    eos_token_id=1,
)
