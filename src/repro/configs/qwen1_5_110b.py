"""Qwen1.5-110B [dense] — 80L, GQA kv=8, QKV bias [hf:Qwen/Qwen1.5-110B]."""
from repro.configs.base import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49_152,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    activation="silu",
    layer_period=((ATTN, MLP),),
    long_context_window=8_192,
    mask_token_id=152_063,
    eos_token_id=151_645,
)
