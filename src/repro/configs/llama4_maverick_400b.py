"""Llama-4-Maverick-400B-A17B [moe] — 128 experts top-1, interleaved MoE/dense,
early fusion [hf:meta-llama/Llama-4-Scout-17B-16E family].

Alternating dense/MoE FFN layers (Maverick interleave); chunked-attention
long-context variant mapped to ``long_context_window`` for long_500k.
"""
from repro.configs.base import ATTN, MLP, MOE, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    rope_theta=500_000.0,
    activation="silu",
    n_experts=128,
    experts_per_token=1,
    moe_d_ff=8192,
    n_shared_experts=1,       # Llama-4 routed + shared expert
    layer_period=((ATTN, MLP), (ATTN, MOE)),
    long_context_window=8_192,   # chunked-attention analog
    mask_token_id=202_047,
    eos_token_id=2,
)
