"""Jamba-v0.1-52B [hybrid] — Mamba+attention 7:1 interleave, MoE every other
layer, 16 experts top-2 [arXiv:2403.19887].

Period of 8 layers: attention at slot 4, Mamba elsewhere; MoE FFN on odd
slots (4 MoE layers / period -> 16 total). CDLM applies in student-only form
(block diffusion over a causal-state backbone), see DESIGN.md §5.
"""
from repro.configs.base import ATTN, MAMBA, MLP, MOE, ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    activation="silu",
    n_experts=16,
    experts_per_token=2,
    moe_d_ff=14_336,
    layer_period=(
        (MAMBA, MLP), (MAMBA, MOE), (MAMBA, MLP), (MAMBA, MOE),
        (ATTN, MLP), (MAMBA, MOE), (MAMBA, MLP), (MAMBA, MOE),
    ),
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    mask_token_id=65_535,
    eos_token_id=2,
)
