"""LLaDA-8B-Instruct — the paper's second target DLM [arXiv:2502 LLaDA].

LLaMA-like MHA backbone trained as a masked diffusion model. Included
alongside the assigned pool (not part of the 10 assigned archs).
"""
from repro.configs.base import ATTN, MLP, ModelConfig

CONFIG = ModelConfig(
    name="llada-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,            # LLaDA uses MHA
    head_dim=128,
    d_ff=12_288,
    vocab_size=126_464,
    activation="silu",
    layer_period=((ATTN, MLP),),
    long_context_window=8_192,
    mask_token_id=126_336,
    eos_token_id=126_081,
)
