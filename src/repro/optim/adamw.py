"""AdamW with global-norm clipping and decay masking — built from scratch
(no optax in this environment). States mirror the param tree so the same
sharding rules apply to optimizer state (FSDP-style)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def _no_decay(path) -> bool:
    """Norm scales / biases / 1-d params are exempt from weight decay."""
    keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    flat = "/".join(str(k) for k in keys)
    return any(s in flat for s in ("norm", "ln_", "mu_", "b", "bias", "w0", "u", "D"))


def warmup_constant_lr(cfg: TrainConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    warm = max(int(cfg.steps * cfg.warmup_frac), 1)

    def lr(step):
        frac = jnp.minimum(step.astype(jnp.float32) / warm, 1.0)
        return cfg.learning_rate * frac

    return lr


def warmup_cosine_lr(cfg: TrainConfig, final_frac: float = 0.05):
    warm = max(int(cfg.steps * cfg.warmup_frac), 1)
    total = max(cfg.steps, warm + 1)

    def lr(step):
        s = step.astype(jnp.float32)
        wfrac = jnp.minimum(s / warm, 1.0)
        prog = jnp.clip((s - warm) / (total - warm), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.learning_rate * wfrac * cos

    return lr


def make_lr_fn(cfg: TrainConfig):
    return (warmup_cosine_lr(cfg) if getattr(cfg, "lr_schedule", "constant")
            == "cosine" else warmup_constant_lr(cfg))


def update(grads, state: AdamWState, params, cfg: TrainConfig,
           lr_fn: Optional[Callable] = None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    lr_fn = lr_fn or warmup_constant_lr(cfg)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_fn(step)
    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if cfg.weight_decay and not _no_decay(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    # three passes (XLA CSEs the duplicate arithmetic under jit); a single
    # pass returning tuples would be ambiguous with tuple-structured params.
    new_params = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v)[0],
        params, grads, state.m, state.v)
    new_m = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v)[1],
        params, grads, state.m, state.v)
    new_v = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v)[2],
        params, grads, state.m, state.v)
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
