"""Shard-aware checkpointing without external deps.

Params are flattened to path-keyed arrays in an ``.npz``. ``save`` gathers
to host (fine at example scale; at production scale each host would write
its own addressable shards — the path-keyed layout is already per-leaf so
that extension is mechanical). ``restore`` needs a template tree (from
``init_model`` or ``jax.eval_shape``) to rebuild structure and dtypes.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(tree, path: str) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(template, path: str):
    with np.load(path) as data:
        flat = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat[0]:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
            arr = data[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(flat[1], leaves)
