"""Per-(arch × input-shape) step functions + ShapeDtypeStruct input specs
for the multi-pod dry-run.

Shapes (assigned):
- train_4k:    the CDLM 3-objective train step (AR step for rwkv6),
               batch 256 × seq 4096 (prompt 2048 + generation 2048).
- prefill_32k: block-causal prompt prefill emitting the exact KV cache.
- decode_32k:  one §4.3 refinement step of the active B=32 block against a
               32k cache (1-token step for rwkv6), batch 128.
- long_500k:   same against a 524288-token cache, batch 1 — sub-quadratic
               paths only (SSM state / SWA / sliding-window decode variant /
               sequence-parallel sharded cache). Skipped for whisper-base
               (DESIGN.md §6).

Everything here is ``jax.eval_shape``-abstract: no parameter or cache is
ever materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    INPUT_SHAPES,
    CDLMConfig,
    ModelConfig,
    TrainConfig,
)
from repro.configs.registry import get_config
from repro.core import masks
from repro.models import forward, init_model
from repro.optim import adamw
from repro.parallel import (
    batch_axes,
    make_sharded_decode_attention,
    param_specs,
)
from repro.training.steps import ar_loss, cdlm_loss


class SkipPair(Exception):
    """(arch, shape) combination intentionally skipped — reason in args."""


BLOCK = 32  # the paper's B


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# Abstract param / cache trees
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    from repro.core.cache import init_cache
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len,
                                             dtype=cfg.dtype))


def cache_shardings(cache_abs, mesh, cfg: ModelConfig, batch: int,
                    *, seq_shard: bool):
    b_ax = batch_axes(mesh, batch)
    kv_ok = cfg.n_kv_heads % mesh.shape["model"] == 0

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("k", "v", "ck", "cv"):
            if seq_shard and name in ("k", "v"):
                return _named(mesh, P(None, b_ax, "model", None, None))
            return _named(mesh, P(None, b_ax, None,
                                  "model" if kv_ok else None, None))
        if name == "ssm":          # (np, b, e, N)
            return _named(mesh, P(None, b_ax, "model", None))
        if name == "conv":         # (np, b, dc-1, e)
            return _named(mesh, P(None, b_ax, None, "model"))
        if name == "S":            # (np, b, H, hs, hs)
            return _named(mesh, P(None, b_ax, "model", None, None))
        if name in ("tm_shift", "cm_shift"):
            return _named(mesh, P(None, b_ax, "model"))
        return _named(mesh, P())

    return jax.tree_util.tree_map_with_path(spec, cache_abs)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DryRunPlan:
    fn: Callable                 # jit-able function
    args: Tuple[Any, ...]        # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    meta: Dict[str, Any]


def _train_plan(cfg: ModelConfig, mesh, shape, *, fsdp: bool = True,
                fwd_kw=None, efficient_loss: bool = False):
    fwd_kw = fwd_kw or {}
    b, L = shape.global_batch, shape.seq_len
    Pl = L // 2
    G = L - Pl
    cdlm = CDLMConfig(block_size=BLOCK, gen_length=G, prompt_length=Pl)
    tcfg = TrainConfig(remat=True)
    b_ax = batch_axes(mesh, b)
    params = abstract_params(cfg)
    pspecs = param_specs(params, mesh, fsdp=fsdp)
    pshard = jax.tree_util.tree_map(lambda s: _named(mesh, s), pspecs)
    opt = jax.eval_shape(adamw.init, params)
    oshard = adamw.AdamWState(
        step=_named(mesh, P()),
        m=jax.tree_util.tree_map(lambda s: _named(mesh, s), pspecs),
        v=jax.tree_util.tree_map(lambda s: _named(mesh, s), pspecs))
    tok = lambda *s: _sds(s, jnp.int32)
    boo = lambda *s: _sds(s, jnp.bool_)

    extras = {}
    extras_shard = {}
    if cfg.is_encoder_decoder:
        extras["encoder_embeds"] = _sds((b, cfg.encoder_seq_len, cfg.d_model),
                                        cfg.dtype)
        extras_shard["encoder_embeds"] = _named(mesh, P(b_ax, None, None))
    if cfg.n_prefix_embeds:
        extras["prefix_embeds"] = _sds((b, cfg.n_prefix_embeds, cfg.d_model),
                                       cfg.dtype)
        extras_shard["prefix_embeds"] = _named(mesh, P(b_ax, None, None))

    if cfg.family == "ssm":
        # CDLM inapplicable (DESIGN.md §5): AR next-token training step
        batch = {"prompt": tok(b, Pl), "answer": tok(b, G),
                 "maskable": boo(b, G)}
        bshard = {k: _named(mesh, P(b_ax, None)) for k in batch}

        def fn(params, opt_state, batch, key):
            (loss, _), grads = jax.value_and_grad(ar_loss, has_aux=True)(
                params, batch, key, cfg=cfg, remat=True, **fwd_kw)
            params, opt_state, _ = adamw.update(grads, opt_state, params, tcfg)
            return params, opt_state, loss
    else:
        student_mode = masks.BLOCK_CAUSAL
        batch = {
            "y": tok(b, L), "y_star": tok(b, L),
            "u_mask": boo(b, L), "s_mask": boo(b, L),
            "teacher_hidden": _sds((b, G, cfg.d_model), cfg.dtype),
            "gt": tok(b, G), "prompt": tok(b, Pl),
        }
        bshard = {
            "y": _named(mesh, P(b_ax, None)),
            "y_star": _named(mesh, P(b_ax, None)),
            "u_mask": _named(mesh, P(b_ax, None)),
            "s_mask": _named(mesh, P(b_ax, None)),
            "teacher_hidden": _named(mesh, P(b_ax, None, None)),
            "gt": _named(mesh, P(b_ax, None)),
            "prompt": _named(mesh, P(b_ax, None)),
        }
        batch.update(extras)
        bshard.update(extras_shard)
        teacher_head = jax.eval_shape(
            lambda: init_model(jax.random.PRNGKey(0), cfg))["embed"]
        th_shard = jax.tree_util.tree_map(
            lambda s: _named(mesh, s), param_specs(teacher_head, mesh,
                                                   fsdp=fsdp))

        def fn(params, opt_state, batch, key, teacher_head):
            extras_in = {k: batch[k] for k in ("encoder_embeds",
                                               "prefix_embeds") if k in batch}
            core = {k: v for k, v in batch.items()
                    if k not in ("encoder_embeds", "prefix_embeds")}
            (loss, _), grads = jax.value_and_grad(cdlm_loss, has_aux=True)(
                params, None, core, key, cfg=cfg, cdlm=cdlm,
                teacher_head=teacher_head, use_lora=False, remat=True,
                student_mode=student_mode, extras=extras_in,
                efficient_loss=efficient_loss, **fwd_kw)
            params, opt_state, _ = adamw.update(grads, opt_state, params, tcfg)
            return params, opt_state, loss

        key = _sds((2,), jnp.uint32)
        return DryRunPlan(
            fn=fn,
            args=(params, opt, batch, key, teacher_head),
            in_shardings=(pshard, oshard, bshard, _named(mesh, P()), th_shard),
            meta={"kind": "train_cdlm", "tokens": b * L,
                  "gen_tokens": b * G})

    key = _sds((2,), jnp.uint32)
    return DryRunPlan(
        fn=fn, args=(params, opt, batch, key),
        in_shardings=(pshard, oshard, bshard, _named(mesh, P())),
        meta={"kind": "train_ar", "tokens": b * L, "gen_tokens": b * G})


def _prefill_plan(cfg: ModelConfig, mesh, shape, *, fsdp: bool = True,
                  fwd_kw=None):
    fwd_kw = fwd_kw or {}
    b, L = shape.global_batch, shape.seq_len
    b_ax = batch_axes(mesh, b)
    params = abstract_params(cfg)
    pshard = jax.tree_util.tree_map(
        lambda s: _named(mesh, s), param_specs(params, mesh, fsdp=fsdp))
    tokens = _sds((b, L), jnp.int32)
    tshard = _named(mesh, P(b_ax, None))
    extras = {}
    eshard = {}
    if cfg.is_encoder_decoder:
        extras["encoder_embeds"] = _sds((b, cfg.encoder_seq_len, cfg.d_model),
                                        cfg.dtype)
        eshard["encoder_embeds"] = _named(mesh, P(b_ax, None, None))
    if cfg.n_prefix_embeds:
        extras["prefix_embeds"] = _sds((b, cfg.n_prefix_embeds, cfg.d_model),
                                       cfg.dtype)
        eshard["prefix_embeds"] = _named(mesh, P(b_ax, None, None))
    mode = masks.CAUSAL if cfg.family == "ssm" else masks.BLOCK_CAUSAL

    attn_impl = fwd_kw.pop("attn_impl",
                           "chunked" if not cfg.is_attention_free else "auto")

    def fn(params, tokens, extras):
        out = forward(params, tokens, cfg=cfg, mode=mode,
                      prompt_len=L + cfg.n_prefix_embeds, block_size=BLOCK,
                      attn_impl=attn_impl, remat=True, **extras, **fwd_kw)
        # emit last-position logits + the cache emissions (committed by the
        # serving layer); returning both is what a server materializes.
        return out.logits[:, -1], out.emissions

    return DryRunPlan(
        fn=fn, args=(params, tokens, extras),
        in_shardings=(pshard, tshard, eshard),
        meta={"kind": "prefill", "tokens": b * L, "gen_tokens": 0})


def _decode_plan(cfg: ModelConfig, mesh, shape, *, fsdp: bool = True,
                 seq_parallel_decode: bool = False, fwd_kw=None):
    fwd_kw = fwd_kw or {}
    b, S = shape.global_batch, shape.seq_len
    long = shape.name == "long_500k"
    if long and cfg.name == "whisper-base":
        raise SkipPair(
            "whisper-base × long_500k: 30 s/1500-frame encoder with a ~448-"
            "token decoder has no meaningful 524k-token decode state "
            "(DESIGN.md §6)")
    if long:
        sub_quadratic = (cfg.is_attention_free or cfg.family in ("hybrid",)
                         or cfg.sliding_window is not None
                         or cfg.long_context_window is not None)
        if not sub_quadratic:
            raise SkipPair(f"{cfg.name} × long_500k: no sub-quadratic path")

    Bq = 1 if (cfg.family == "ssm" ) else BLOCK
    b_ax = batch_axes(mesh, b)
    params = abstract_params(cfg)
    pshard = jax.tree_util.tree_map(
        lambda s: _named(mesh, s), param_specs(params, mesh, fsdp=fsdp))

    # attention-free archs carry O(1) state, no (b, S, kv, hd) buffers
    cache_abs = abstract_cache(cfg, b, 0 if cfg.is_attention_free else S)
    # long-context always seq-shards the cache; decode_32k seq-shards only
    # under the --seq-parallel-decode §Perf variant
    seq_shard = ((long or seq_parallel_decode)
                 and not cfg.is_attention_free)
    cshard = cache_shardings(cache_abs, mesh, cfg, b, seq_shard=seq_shard)

    tokens = _sds((b, Bq), jnp.int32)
    tshard = _named(mesh, P(b_ax, None))
    clen = _sds((), jnp.int32)

    use_long_window = bool(long and cfg.long_context_window)
    mode = masks.CAUSAL if cfg.family == "ssm" else masks.BLOCK_CAUSAL
    dec_fn = None
    if seq_parallel_decode and seq_shard:
        dec_fn = make_sharded_decode_attention(mesh, batch_axis=b_ax)

    attn_impl = fwd_kw.pop("attn_impl",
                           "chunked" if S > 65536 else "auto")

    def fn(params, tokens, cache, cache_len):
        out = forward(params, tokens, cfg=cfg, mode=mode, prompt_len=0,
                      block_size=Bq if Bq > 1 else 1,
                      positions=cache_len + jnp.arange(Bq),
                      cache=cache, cache_len=cache_len,
                      use_long_window=use_long_window,
                      decode_attention_fn=dec_fn,
                      attn_impl=attn_impl, **fwd_kw)
        return out.logits, out.emissions

    return DryRunPlan(
        fn=fn, args=(params, tokens, cache_abs, clen),
        in_shardings=(pshard, tshard, cshard, _named(mesh, P())),
        meta={"kind": "decode", "tokens": b * Bq, "gen_tokens": b * Bq,
              "cache_len": S, "seq_shard": seq_shard})


def build_plan(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               seq_parallel_decode: bool = False,
               roofline_periods: Optional[int] = None,
               efficient_loss: bool = False) -> DryRunPlan:
    """``roofline_periods=k`` builds a depth-k *unrolled* variant with dense
    attention for cost extrapolation (XLA counts scan/while bodies once in
    cost_analysis, so the scanned full-depth compile under-reports FLOPs —
    the dry-run proof still uses the scanned version)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    fwd_kw = {}
    if roofline_periods is not None:
        k = roofline_periods
        cfg = dataclasses.replace(
            cfg, n_layers=k * len(cfg.layer_period),
            n_encoder_layers=(k if cfg.is_encoder_decoder else 0))
        fwd_kw = {"unroll_layers": True}
        # dense attention fully counts score FLOPs/bytes in cost_analysis
        # (chunked hides them inside scan bodies) — but dense at Lk=32k is a
        # pathological SPMD compile, so prefill keeps chunked and the
        # attention part is added analytically (see dryrun.extrapolate).
        if shape_name != "prefill_32k":
            fwd_kw["attn_impl"] = "dense"
    if shape.kind == "train":
        return _train_plan(cfg, mesh, shape, fsdp=fsdp, fwd_kw=fwd_kw,
                           efficient_loss=efficient_loss)
    if shape.kind == "prefill":
        return _prefill_plan(cfg, mesh, shape, fsdp=fsdp, fwd_kw=fwd_kw)
    return _decode_plan(cfg, mesh, shape, fsdp=fsdp,
                        seq_parallel_decode=seq_parallel_decode,
                        fwd_kw=fwd_kw)
