"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --stage teacher --steps 500 [--reduced]

Stages: ``teacher`` (Eq.-6 DLM SFT), ``ar`` (AR baseline / rwkv path),
``cdlm`` (the full teacher->trajectories->student pipeline). On this
CPU container only ``--reduced`` configs are trainable; on a real TPU mesh
the same code path shards via ``repro.parallel`` (see launch/dryrun.py for
the production-mesh proof of every arch × shape).
"""
import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--stage", default="cdlm",
                    choices=["teacher", "ar", "cdlm"])
    ap.add_argument("--task", default="sort", choices=["sort", "add"])
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--student-steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--block-size", type=int, default=5)
    ap.add_argument("--lora", action="store_true")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    from repro.checkpoint import save
    from repro.configs.base import CDLMConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.core import masks
    from repro.data import Corpus, TaskSpec
    from repro.training import trainer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    task = TaskSpec(args.task, vocab_size=cfg.vocab_size, prompt_len=15,
                    gen_len=10, sort_k=8, sort_range=24, add_digits=4)
    corpus = Corpus(task, 768, seed=0)
    tcfg = TrainConfig(learning_rate=args.lr, steps=args.steps,
                       batch_size=args.batch_size, remat=False,
                       use_lora=args.lora)

    if args.stage == "ar" or cfg.family == "ssm":
        params = trainer.train_ar(cfg, corpus, tcfg)
    elif args.stage == "teacher":
        params = trainer.train_teacher(cfg, corpus, tcfg)
    else:
        cdlm_cfg = CDLMConfig(block_size=args.block_size, gen_length=10,
                              prompt_length=15, temperatures=(0.0,))
        mode = (masks.BLOCK_CAUSAL if cfg.family == "hybrid"
                else masks.BIDIRECTIONAL)
        teacher = trainer.train_teacher(cfg, corpus, tcfg, mode=mode,
                                        block_size=args.block_size)
        ds = trainer.collect_dataset(teacher, cfg, cdlm_cfg, corpus,
                                     n_examples=128, batch=args.batch_size)
        scfg = dataclasses.replace(tcfg, steps=args.student_steps,
                                   learning_rate=5e-4)
        params = trainer.train_student(teacher, ds, cfg, cdlm_cfg, scfg)

    if args.ckpt:
        save(params, args.ckpt)
        print(f"saved -> {args.ckpt}")


if __name__ == "__main__":
    main()
