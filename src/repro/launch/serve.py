"""Serving launcher CLI: load a checkpoint (or train the cached toy assets)
and serve batched requests with any sampler strategy, under either the
static or the continuous block-level batching scheduler.

    PYTHONPATH=src python -m repro.launch.serve --sampler cdlm --requests 32
    PYTHONPATH=src python -m repro.launch.serve --scheduler continuous

With ``--http`` the engine is exposed through the stdlib HTTP frontend
(``repro.serving.server``) instead of replaying a local batch: an
OpenAI-style ``POST /v1/completions`` (SSE streaming and non-streaming),
``GET /healthz`` and ``GET /metrics``:

    PYTHONPATH=src python -m repro.launch.serve --scheduler continuous \
        --http --port 8000
"""
import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sampler", default="cdlm",
                    choices=["vanilla", "fast_dllm", "dual_cache",
                             "interval_cache", "cdlm", "ar"])
    ap.add_argument("--scheduler", default="static",
                    choices=["static", "continuous"],
                    help="continuous = slot-based block-level batching "
                         "(cdlm only)")
    ap.add_argument("--cache-layout", default="dense",
                    choices=["dense", "paged"],
                    help="KV memory layout: dense per-lane buffers, or a "
                         "global page pool + per-lane page tables "
                         "(page size = block size)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="paged layout: page-pool size in pages "
                         "(default: dense-equivalent capacity)")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="paged + continuous only: decode through the "
                         "Pallas page-table flash-decode kernel instead of "
                         "the bit-exact gather path (interpret mode on CPU)")
    ap.add_argument("--fused-select", action="store_true",
                    help="fused unembed + online-softmax candidate "
                         "selection (repro.kernels.select): decode skips "
                         "the lm_head and never materializes (b, ., V) "
                         "logits; greedy decoding only")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--threshold", type=float, default=0.9)
    ap.add_argument("--ckpt", default=None,
                    help="npz checkpoint (defaults to cached bench assets)")
    ap.add_argument("--http", action="store_true",
                    help="serve over HTTP (/v1/completions with SSE "
                         "streaming, /healthz, /metrics) instead of "
                         "replaying a local request batch")
    ap.add_argument("--host", default=None,
                    help="HTTP bind host (default: ServeConfig.http_host)")
    ap.add_argument("--port", type=int, default=None,
                    help="HTTP bind port (default: ServeConfig.http_port)")
    args = ap.parse_args()
    if args.paged_kernel and (args.scheduler != "continuous"
                              or args.cache_layout != "paged"):
        ap.error("--paged-kernel requires --scheduler continuous "
                 "--cache-layout paged")

    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    "..", "..", ".."))
    from benchmarks import common
    from repro.configs.base import ServeConfig
    from repro.serving import Request, efficiency_report, make_engine

    if args.ckpt:
        import jax
        from repro.checkpoint import restore
        from repro.models import init_model
        params = restore(init_model(jax.random.PRNGKey(0), common.CFG),
                         args.ckpt)
    else:
        params = (common.get_student() if args.sampler == "cdlm"
                  else common.get_teacher())

    serve = ServeConfig(max_batch=args.batch,
                        block_size=common.CDLM_CFG.block_size,
                        gen_length=common.TASK.gen_len,
                        sampler=args.sampler,
                        conf_threshold=args.threshold,
                        scheduler=args.scheduler,
                        cache_layout=args.cache_layout,
                        page_pool_pages=args.pool_pages,
                        fused_select=args.fused_select)
    kw = {"use_paged_kernel": True} if args.paged_kernel else {}
    eng = make_engine(params, common.CFG, serve,
                      prompt_len=common.TASK.prompt_len, **kw)
    if args.http:
        from repro.serving.server import serve_http
        host = args.host if args.host is not None else serve.http_host
        port = args.port if args.port is not None else serve.http_port
        eng.warmup(per_request=True)
        print(f"serving /v1/completions on http://{host}:{port} "
              f"(prompt_len={common.TASK.prompt_len}, "
              f"scheduler={args.scheduler}) — Ctrl-C to stop")
        serve_http(eng, host, port)
        return
    ev = common.corpus().eval_batch(args.requests)
    reqs = [Request(prompt=p, id=i) for i, p in enumerate(ev["prompt"])]
    eng.warmup()
    t0 = time.perf_counter()
    resp = eng.generate(reqs)
    wall = time.perf_counter() - t0
    rep = efficiency_report(resp)
    # wall-clock TPS is comparable across schedulers; latency_s is not
    # (compute share for static, arrival->completion for continuous)
    tps = sum(r.gen_length for r in resp) / wall if wall else 0.0
    print(f"{args.sampler}/{args.scheduler}: TPS={tps:.0f} "
          f"latency={rep['latency_s']*1e3:.1f}ms steps={rep['steps']:.1f} "
          f"gen_len={rep['gen_length']:.1f}  ({len(resp)} requests)")
    if args.cache_layout == "paged" and args.scheduler == "continuous":
        ps = eng.page_pool_stats()
        print(f"page pool: {ps['peak_pages']:.0f}/{ps['n_pages']:.0f} pages "
              f"peak ({ps['peak_occupancy']:.0%}), "
              f"{ps['preemptions']:.0f} preemptions, "
              f"{ps['stall_rounds']:.0f} stall rounds")


if __name__ == "__main__":
    main()
