import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination on 512 placeholder host devices, print memory/cost analysis and
record the three-term roofline (brief §MULTI-POD DRY-RUN / §ROOFLINE).

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
  python -m repro.launch.dryrun ... --seq-parallel-decode   # §Perf variant
Results append to experiments/dryrun/<tag>.json.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ASSIGNED_IDS, get_config
from repro.launch.mesh import make_production_mesh, n_chips
from repro.launch.specs import SkipPair, build_plan
from repro.roofline import analyze


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            fsdp: bool = True, seq_parallel_decode: bool = False,
            efficient_loss: bool = False, verbose: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.time()
    plan = build_plan(arch, shape_name, mesh, fsdp=fsdp,
                      seq_parallel_decode=seq_parallel_decode,
                      efficient_loss=efficient_loss)
    with mesh:
        lowered = jax.jit(plan.fn, in_shardings=plan.in_shardings).lower(
            *plan.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            }
    except Exception:
        pass

    report = analyze(compiled, lowered, cfg=get_config(arch),
                     shape_name=shape_name, mesh_name=mesh_name,
                     chips=n_chips(mesh), tokens=plan.meta["tokens"],
                     kind=plan.meta["kind"])
    rec = report.to_dict()
    rec.update({"status": "ok", "lower_s": t_lower, "compile_s": t_compile,
                "memory_analysis": mem, "meta": plan.meta,
                "fsdp": fsdp, "seq_parallel_decode": seq_parallel_decode})
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] OK  "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
        if mem:
            print(f"  memory_analysis/chip: temp={mem['temp_bytes']/2**30:.2f}GiB "
                  f"args={mem['argument_bytes']/2**30:.2f}GiB "
                  "(HBM/chip: 16GiB)")
        print(f"  cost: {rec['hlo_flops']:.3e} FLOPs, "
              f"{rec['hlo_bytes']:.3e} B accessed, "
              f"{rec['coll_bytes']:.3e} B collectives "
              f"{rec['coll_detail']['counts']}")
        print(f"  roofline terms/chip: compute={rec['compute_s']*1e3:.2f}ms "
              f"memory={rec['memory_s']*1e3:.2f}ms "
              f"collective={rec['collective_s']*1e3:.2f}ms "
              f"-> {rec['bottleneck']}-bound; useful={rec['useful_ratio']:.2f}")
    return rec


def _cost_of(arch, shape_name, mesh, k, **kw):
    plan = build_plan(arch, shape_name, mesh, roofline_periods=k, **kw)
    with mesh:
        lowered = jax.jit(plan.fn, in_shardings=plan.in_shardings).lower(
            *plan.args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    from repro.roofline.hlo import collective_bytes
    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text()
    coll = collective_bytes(text)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_wire": coll["wire_bytes"],
            "coll_total": coll["total_bytes"]}


def _analytic_prefill_attention(cfg, shape, chips):
    """Per-chip attention score FLOPs + flash KV-restream bytes for the
    prefill shape — the chunked ("flash") impl hides these inside scan
    bodies, so the extrapolated prefill costs add them analytically
    (EXPERIMENTS.md §Dry-run measurement note)."""
    from repro.configs.base import ATTN, ATTN_LOCAL
    b = shape.global_batch
    Lq = shape.seq_len + cfg.n_prefix_embeds
    flops_pp = 0.0
    bytes_pp = 0.0
    q_tile = 1024
    for mixer, _ in cfg.layer_period:
        if mixer not in (ATTN, ATTN_LOCAL):
            continue
        Lk_eff = (min(cfg.sliding_window, Lq) if mixer == ATTN_LOCAL
                  and cfg.sliding_window else Lq)
        flops_pp += 4.0 * Lq * Lk_eff * cfg.n_heads * cfg.head_dim * b / chips
        bytes_pp += ((Lq / q_tile) * Lk_eff * cfg.n_kv_heads * cfg.head_dim
                     * 2 * b / chips)
    return flops_pp, bytes_pp


def extrapolate_record(rec, *, multi_pod=False, fsdp=True,
                       seq_parallel_decode=False, efficient_loss=False):
    """Correct the scan-undercounted costs: compile unrolled depth-1 and
    depth-2 variants, extrapolate linearly to the full period count.
    (XLA cost_analysis counts while/scan bodies once — verified.)"""
    from repro.configs.base import INPUT_SHAPES, TPU_V5E
    arch, shape = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kw = dict(fsdp=fsdp, seq_parallel_decode=seq_parallel_decode,
              efficient_loss=efficient_loss)
    c1 = _cost_of(arch, shape, mesh, 1, **kw)
    c2 = _cost_of(arch, shape, mesh, 2, **kw)
    n = cfg.n_periods
    ex = {key: c1[key] + (c2[key] - c1[key]) * (n - 1) for key in c1}
    if shape == "prefill_32k":
        af, ab = _analytic_prefill_attention(cfg, INPUT_SHAPES[shape],
                                             rec["chips"])
        ex["flops"] += af * n
        ex["bytes"] += ab * n
        ex["analytic_attention"] = {"flops_per_period": af,
                                    "bytes_per_period": ab}
    hw = TPU_V5E
    rec["raw_scan"] = {k: rec[k] for k in
                       ("hlo_flops", "hlo_bytes", "coll_bytes", "compute_s",
                        "memory_s", "collective_s", "bottleneck",
                        "useful_ratio")}
    rec["hlo_flops"] = ex["flops"]
    rec["hlo_bytes"] = ex["bytes"]
    rec["coll_bytes"] = ex["coll_total"]
    rec["compute_s"] = ex["flops"] / hw.peak_flops
    rec["memory_s"] = ex["bytes"] / hw.hbm_bw
    rec["collective_s"] = ex["coll_wire"] / hw.ici_bw
    terms = {"compute": rec["compute_s"], "memory": rec["memory_s"],
             "collective": rec["collective_s"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    rec["useful_ratio"] = (rec["model_flops"] / (ex["flops"] * rec["chips"])
                           if ex["flops"] else 0.0)
    rec["extrapolated"] = {"per_period": {k: c2[k] - c1[k] for k in c1},
                           "base": c1, "n_periods": n,
                           "note": "unrolled depth-1/2 dense-attention "
                                   "variants, linear in periods"}
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-parallel-decode", action="store_true")
    ap.add_argument("--efficient-loss", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--extrapolate", action="store_true",
                    help="correct scan-undercounted roofline costs on "
                         "existing records via depth-1/2 unrolled compiles")
    args = ap.parse_args()

    if args.extrapolate:
        tag = args.out or ("dryrun_multipod" if args.multi_pod else "dryrun")
        path = os.path.join("experiments", "dryrun", f"{tag}.json")
        results = json.load(open(path))
        archs = list(ASSIGNED_IDS) if args.arch == "all" else [args.arch]
        shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
        for rec in results:
            if (rec.get("status") != "ok" or rec["arch"] not in archs
                    or rec["shape"] not in shapes
                    or "extrapolated" in rec
                    or rec.get("seq_parallel_decode", False)
                    != args.seq_parallel_decode):
                continue
            t0 = time.time()
            try:
                extrapolate_record(rec, multi_pod=args.multi_pod,
                                   fsdp=not args.no_fsdp,
                                   seq_parallel_decode=args.seq_parallel_decode)
                print(f"[{rec['arch']} × {rec['shape']}] extrapolated "
                      f"({time.time()-t0:.0f}s) -> {rec['bottleneck']}-bound "
                      f"compute={rec['compute_s']*1e3:.1f}ms "
                      f"memory={rec['memory_s']*1e3:.1f}ms "
                      f"coll={rec['collective_s']*1e3:.1f}ms "
                      f"useful={rec['useful_ratio']:.2f}")
            except Exception as e:
                print(f"[{rec['arch']} × {rec['shape']}] extrapolation "
                      f"failed: {type(e).__name__}: {e}")
            json.dump(results, open(path, "w"), indent=1, default=str)
        return

    archs = list(ASSIGNED_IDS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    tag = args.out or ("dryrun_multipod" if args.multi_pod else "dryrun")
    path = os.path.join("experiments", "dryrun", f"{tag}.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    results = []
    if os.path.exists(path):
        results = json.load(open(path))
    have = {(r["arch"], r["shape"], r.get("seq_parallel_decode", False),
             r.get("fsdp", True)) for r in results if r.get("status") == "ok"}

    for arch in archs:
        for shape in shapes:
            key = (arch, shape, args.seq_parallel_decode, not args.no_fsdp)
            if key in have:
                print(f"[{arch} × {shape}] cached, skip")
                continue
            try:
                rec = run_one(arch, shape, multi_pod=args.multi_pod,
                              fsdp=not args.no_fsdp,
                              seq_parallel_decode=args.seq_parallel_decode,
                              efficient_loss=args.efficient_loss)
            except SkipPair as e:
                rec = {"arch": arch, "shape": shape, "status": "skipped",
                       "reason": str(e)}
                print(f"[{arch} × {shape}] SKIP: {e}")
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[{arch} × {shape}] ERROR: {type(e).__name__}: {e}")
            results = [r for r in results
                       if not (r["arch"] == arch and r["shape"] == shape
                               and r.get("seq_parallel_decode", False)
                               == args.seq_parallel_decode
                               and r.get("fsdp", True) == (not args.no_fsdp))]
            results.append(rec)
            json.dump(results, open(path, "w"), indent=1, default=str)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"\n{n_ok}/{len(results)} OK -> {path}")


if __name__ == "__main__":
    main()
