import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimb driver: run named dry-run variants for the three chosen
(arch × shape) pairs and record extrapolation-corrected roofline terms.

    PYTHONPATH=src python -m repro.launch.perf [--only tag]
"""
import argparse
import json
import time
import traceback

from repro.launch.dryrun import extrapolate_record, run_one

# (tag, arch, shape, run_one kwargs)
VARIANTS = [
    # H1 — worst useful-ratio + the paper's own training step
    ("h1_train_slicefix", "qwen2-0.5b", "train_4k", {}),
    ("h1_train_efficient_loss", "qwen2-0.5b", "train_4k",
     {"efficient_loss": True}),
    # H2 — most collective-bound decode (MoE all-to-all), §4.3 serving step
    ("h2_kimi_decode_slicefix", "kimi-k2-1t-a32b", "decode_32k", {}),
    ("h2_kimi_decode_seqpar", "kimi-k2-1t-a32b", "decode_32k",
     {"seq_parallel_decode": True}),
    # H1 iteration 2+3: replicate lm-head contraction dim + one-hot
    # token-logp contraction (see sharding.py / losses.py comments)
    ("h1_train_headfix", "qwen2-0.5b", "train_4k",
     {"efficient_loss": True}),
    # H1 iteration 3: Megatron-style fused-axis sharding of the
    # non-contraction weight dims (kills the batch-replication all-reduces)
    ("h1_train_tpfsdp_fix", "qwen2-0.5b", "train_4k",
     {"efficient_loss": True}),
    ("h1b_110b_train_tpfsdp", "qwen1.5-110b", "train_4k",
     {"efficient_loss": True}),
    # H2 iteration 2: bounded dropless capacity (C = 8x balanced load)
    ("h2_kimi_decode_capfix", "kimi-k2-1t-a32b", "decode_32k", {}),
    # H3 — long-context decode, beyond-paper sequence-parallel cache
    ("h3_110b_long_slicefix", "qwen1.5-110b", "long_500k", {}),
    ("h3_110b_long_seqpar", "qwen1.5-110b", "long_500k",
     {"seq_parallel_decode": True}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    path = os.path.join("experiments", "perf.json")
    results = json.load(open(path)) if os.path.exists(path) else {}

    for tag, arch, shape, kw in VARIANTS:
        if args.only and args.only not in tag:
            continue
        if tag in results:
            print(f"[{tag}] cached")
            continue
        t0 = time.time()
        try:
            rec = run_one(arch, shape, verbose=False, **kw)
            extrapolate_record(rec, seq_parallel_decode=kw.get(
                "seq_parallel_decode", False),
                efficient_loss=kw.get("efficient_loss", False))
            rec["tag"] = tag
            rec["variant_kwargs"] = kw
            results[tag] = rec
            print(f"[{tag}] ({time.time()-t0:.0f}s) "
                  f"compute={rec['compute_s']*1e3:.1f}ms "
                  f"memory={rec['memory_s']*1e3:.1f}ms "
                  f"collective={rec['collective_s']*1e3:.1f}ms "
                  f"-> {rec['bottleneck']}-bound useful={rec['useful_ratio']:.2f}")
            top = rec["coll_detail"]["top_ops"][:3]
            for op in top:
                print(f"    top-coll: {op['kind']} {op['bytes']/2**20:.1f}MiB "
                      f"{op['shape']}")
        except Exception as e:
            print(f"[{tag}] FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
        json.dump(results, open(path, "w"), indent=1, default=str)


if __name__ == "__main__":
    main()
