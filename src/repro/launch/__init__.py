# NOTE: do not import jax (or anything that initializes jax) at package
# import time here — dryrun.py must be able to set XLA_FLAGS first.
