"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_tiny_mesh(*, data: int = 2, model: int = 4):
    """Small mesh for CPU distributed tests (8 forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def n_chips(mesh) -> int:
    return mesh.devices.size
