from repro.serving.api import (  # noqa: F401
    BlockEvent,
    GenerationOutput,
    GenerationRequest,
    Request,
    Response,
    SamplingParams,
)
from repro.serving.engine import (  # noqa: F401
    ContinuousEngine,
    Engine,
    efficiency_report,
    make_engine,
)
