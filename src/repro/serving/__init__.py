from repro.serving.engine import (  # noqa: F401
    ContinuousEngine,
    Engine,
    Request,
    Response,
    efficiency_report,
    make_engine,
)
