from repro.serving.engine import Engine, Request, Response, efficiency_report  # noqa: F401
