"""Batched serving engines.

Two schedulers over the unified block-decode core
(``repro.core.block_loop``):

- :class:`Engine` — **static batching**: requests are padded into
  fixed-shape batches and each batch runs the full jitted sampler to
  completion. Simple, works with every sampler strategy, but lanes that
  finish early (EOS / short ``max_tokens``) burn compute as padding until
  the whole batch drains.

- :class:`ContinuousEngine` — **continuous block-level batching**: a
  persistent decode batch of ``max_batch`` lanes advances one *block* per
  jitted step, each lane at its own block offset
  (:func:`repro.core.block_loop.lane_block_forward`). At every block
  boundary finished lanes are evicted, their cache rows reset
  (:func:`repro.core.cache.reset`), and queued requests admitted mid-flight
  (prompt prefill committed into the freed rows via ``commit_rows``).
  Block-causal cache exactness makes lane recycling loss-free, so a lane
  admitted mid-flight decodes bit-identically to one decoded in isolation.

The continuous engine runs over either KV layout
(``ServeConfig.cache_layout``):

- ``dense``: per-lane ``max_len`` KV rows — admission is slot-bound.
- ``paged``: a global page pool (page size = block size) with per-lane page
  tables (:class:`repro.core.cache.PagedCache`). Admission is *page*-bound:
  a request is admitted whenever pages for its prompt and next block exist
  (no whole-sequence reservation), each block boundary allocates just the
  pages the live lanes' next blocks need, and eviction returns a lane's
  pages to the pool. Lanes that cannot get their next page stall for a
  round; if every live lane stalls, the youngest lane is preempted (pages
  freed, request requeued — loss-free, since re-decoding from scratch is
  deterministic). A pool holding one full canvas is the deadlock-free
  minimum; sizing it below ``max_batch`` full canvases is what buys
  higher concurrency per HBM byte at mixed generation lengths.

Metrics follow the paper (Tables 1–2): per-request latency, TPS (valid
tokens / wall-clock), refinement steps, generation length. The continuous
engine reports true per-request latency (arrival → completion, queueing
included) instead of a per-chunk average.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import cache as C
from repro.core import diffusion as D
from repro.core import masks
from repro.core.block_loop import (
    SamplerSpec,
    _gen_lengths,
    init_canvas,
    lane_block_forward,
)
from repro.core.sampler import SAMPLERS
from repro.models import forward, unembed_matrix


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                       # (P,) int32
    extras: Optional[Dict[str, np.ndarray]] = None
    id: int = 0
    max_tokens: Optional[int] = None         # per-request generation cap
    arrival_s: float = 0.0                   # arrival offset in the trace


@dataclasses.dataclass
class Response:
    id: int
    tokens: np.ndarray                       # generated span (gen_len,)
    gen_length: int
    steps: int
    # static Engine: per-sample share of batch compute time (arrival_s is
    # not modeled); ContinuousEngine: true arrival -> completion, queueing
    # included. Compare throughput across engines via wall-clock, not this.
    latency_s: float
    queue_s: float = 0.0                     # arrival -> admission (continuous)


def _validate_requests(requests: Sequence[Request]) -> None:
    keys0 = frozenset(requests[0].extras or {})
    for r in requests:
        if frozenset(r.extras or {}) != keys0:
            raise ValueError(
                "all requests in a batch must carry the same extras keys: "
                f"request {requests[0].id} has {sorted(keys0)}, request "
                f"{r.id} has {sorted(r.extras or {})}")


class Engine:
    """Static fixed-shape batching over any sampler strategy."""

    def __init__(self, params, cfg: ModelConfig, serve: ServeConfig,
                 prompt_len: int, *, pos_offset: int = 0,
                 use_long_window: bool = False):
        if serve.page_pool_pages is not None:
            raise ValueError(
                "page_pool_pages is only honored by the continuous "
                "scheduler with the paged layout; the static engine runs "
                "whole sequences to completion, so its paged pool is "
                "always sized dense-equivalent (batch x full canvas)")
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.spec = SamplerSpec(
            prompt_len=prompt_len, gen_len=serve.gen_length,
            block_size=serve.block_size, conf_threshold=serve.conf_threshold,
            temperature=serve.temperature,
            cache_refresh_interval=serve.cache_refresh_interval,
            pos_offset=pos_offset, cache_layout=serve.cache_layout,
            fused_select=serve.fused_select)
        sampler = SAMPLERS[serve.sampler]
        kwargs = {}
        if serve.sampler == "cdlm" and use_long_window:
            kwargs["use_long_window"] = True

        def run(params, prompts, key, extras):
            return sampler(params, prompts, cfg=cfg, spec=self.spec, key=key,
                           extras=extras, **kwargs)

        self._run = jax.jit(run)
        self._warm = False

    def warmup(self, extras=None):
        b = self.serve.max_batch
        prompts = jnp.zeros((b, self.spec.prompt_len), jnp.int32)
        self._run(self.params, prompts, jax.random.PRNGKey(0),
                  extras or {}).tokens.block_until_ready()
        self._warm = True

    def generate(self, requests: Sequence[Request],
                 key=None) -> List[Response]:
        if not requests:
            return []
        _validate_requests(requests)
        key = key if key is not None else jax.random.PRNGKey(0)
        out: List[Response] = []
        B = self.serve.max_batch
        for i in range(0, len(requests), B):
            chunk = list(requests[i:i + B])
            pad = B - len(chunk)
            prompts = np.stack([r.prompt for r in chunk] +
                               [chunk[-1].prompt] * pad)
            extras = {}
            if chunk[0].extras:
                for k in chunk[0].extras:
                    arrs = [r.extras[k] for r in chunk] + [chunk[-1].extras[k]] * pad
                    extras[k] = jnp.asarray(np.stack(arrs))
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            res = self._run(self.params, jnp.asarray(prompts), sub, extras)
            res.tokens.block_until_ready()
            dt = (time.perf_counter() - t0) / len(chunk)
            toks = np.asarray(res.tokens)
            steps = np.asarray(res.steps)
            glens = np.asarray(res.gen_lengths)
            for j, r in enumerate(chunk):
                glen = int(glens[j])
                if r.max_tokens is not None:
                    glen = min(glen, r.max_tokens)
                out.append(Response(
                    id=r.id, tokens=toks[j, self.spec.prompt_len:],
                    gen_length=glen, steps=int(steps[j]),
                    latency_s=dt))
        return out


# ---------------------------------------------------------------------------
# Continuous block-level batching
# ---------------------------------------------------------------------------
class _SlotState(NamedTuple):
    tokens: jnp.ndarray       # (N, P+G) canvases
    cache: Any                # batch KV cache, lanes on axis 1
    blk: jnp.ndarray          # (N,) int32 — each lane's current block index
    lane_nblocks: jnp.ndarray  # (N,) int32 — blocks this request decodes
    live: jnp.ndarray         # (N,) bool — lane occupied and unfinished
    steps: jnp.ndarray        # (N,) int32 refinement iterations
    calls: jnp.ndarray        # () int32 total forward passes
    key: jnp.ndarray


class ContinuousEngine:
    """Slot-based continuous batching over the CDLM exact-cache strategy.

    Scheduling happens at block boundaries: each jitted ``_decode_block``
    call advances every live lane by one block (threshold refinement +
    commit pass); between calls the host evicts finished lanes and admits
    arrived requests into the freed slots. Only the ``cdlm`` strategy is
    supported — approximate-cache strategies refresh KV from the *whole*
    canvas, which couples lanes to batch-global state, and only the exact
    block-causal cache makes per-lane recycling loss-free.
    """

    def __init__(self, params, cfg: ModelConfig, serve: ServeConfig,
                 prompt_len: int, *, use_long_window: bool = False,
                 use_paged_kernel: bool = False):
        if serve.sampler != "cdlm":
            raise ValueError(
                "ContinuousEngine requires the 'cdlm' strategy (exact "
                f"block-causal cache); got sampler={serve.sampler!r}")
        if use_paged_kernel and serve.cache_layout != C.PAGED:
            raise ValueError("use_paged_kernel requires cache_layout='paged'")
        if cfg.is_encoder_decoder:
            raise ValueError("ContinuousEngine does not support "
                             "encoder-decoder models yet (per-lane encoder "
                             "state is not scheduled)")
        if serve.temperature > 0:
            # all lanes share one RNG split per joint refinement iteration,
            # so sampled decoding would depend on which requests happen to
            # share the batch — breaking the isolation-exactness guarantee.
            # Per-lane RNG streams are needed before this can be allowed.
            raise ValueError("ContinuousEngine currently supports greedy "
                             "decoding only (temperature=0); got "
                             f"temperature={serve.temperature}")
        if serve.cache_layout not in C.CACHE_LAYOUTS:
            raise ValueError(f"unknown cache layout {serve.cache_layout!r} "
                             f"(expected one of {C.CACHE_LAYOUTS})")
        if (serve.cache_layout != C.PAGED
                and serve.page_pool_pages is not None):
            raise ValueError("page_pool_pages requires cache_layout='paged' "
                             "— the dense layout preallocates per-lane "
                             "buffers and would silently ignore the budget")
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.spec = SamplerSpec(
            prompt_len=prompt_len, gen_len=serve.gen_length,
            block_size=serve.block_size, conf_threshold=serve.conf_threshold,
            temperature=serve.temperature, early_stop=True,
            cache_layout=serve.cache_layout, fused_select=serve.fused_select)
        # fused unembed+select decode: lane forwards skip the lm_head and
        # candidates/confidences come from the vocab-tiled selection kernel
        # — no (b, B, V) logits in the refinement loop
        self._fused = serve.fused_select
        self.n_lanes = serve.max_batch
        self.paged = serve.cache_layout == C.PAGED
        P, B = prompt_len, serve.block_size
        T = prompt_len + serve.gen_length
        if self.paged:
            self._n_tables = -(-T // B)
            self.n_pages = (serve.page_pool_pages
                            if serve.page_pool_pages is not None
                            else self.n_lanes * self._n_tables)
            if self.n_pages < self._n_tables:
                raise ValueError(
                    f"page pool of {self.n_pages} pages cannot back one "
                    f"full request ({self._n_tables} pages of {B} tokens "
                    f"for prompt {P} + gen {serve.gen_length}) — this is "
                    "the deadlock-free minimum")
            # pages a fresh request needs at admission: prompt + first block
            self._admit_pages = C.pages_for_span(0, P + B, B)
        else:
            self.n_pages = 0
        self._use_long_window = use_long_window
        # opt-in Pallas flash-decode over the page table (TPU hot path;
        # interpret-mode on CPU — numerically equal to the gather path to
        # fp32 tolerance, not bit-equal, since reduction order differs)
        self._paged_attention_fn = None
        if use_paged_kernel:
            from repro.kernels.decode_attn import paged_decode_attention
            self._paged_attention_fn = paged_decode_attention
        self._jit_admit = jax.jit(self._admit)
        self._jit_decode_block = jax.jit(self._decode_block)
        self._jit_evict = jax.jit(self._evict)
        self._jit_alloc_block = jax.jit(self._alloc_block)
        self._jit_gen_lengths = jax.jit(
            lambda tokens: _gen_lengths(tokens, self.spec, self.cfg))
        self._warm = False
        self._pool_samples: List[int] = []
        self._live_samples: List[int] = []
        self._preemptions = 0
        self._stall_rounds = 0

    # -- jitted state transitions -------------------------------------------
    def _init_state(self, key) -> _SlotState:
        N = self.n_lanes
        T = self.spec.prompt_len + self.spec.gen_len
        if self.paged:
            cache = C.init_paged_cache(
                self.cfg, N, self._n_tables * self.spec.block_size,
                n_pages=self.n_pages, page_size=self.spec.block_size,
                dtype=self.cfg.dtype)
        else:
            cache = C.init_cache(self.cfg, N, T, dtype=self.cfg.dtype)
        return _SlotState(
            tokens=jnp.full((N, T), self.cfg.mask_token_id, jnp.int32),
            cache=cache,
            blk=jnp.zeros((N,), jnp.int32),
            lane_nblocks=jnp.full((N,), self.spec.n_blocks, jnp.int32),
            live=jnp.zeros((N,), bool),
            steps=jnp.zeros((N,), jnp.int32),
            calls=jnp.zeros((), jnp.int32),
            key=key)

    def _admit(self, params, state: _SlotState, prompts, admit, nblocks):
        """Admit requests into freed lanes: write canvases, reset cache rows
        (paged: allocate prompt + first-block pages), prefill prompts under
        the block-causal mask, commit into those rows.

        Returns ``(state, ok)`` — ``ok`` is the admitted-lane mask that got
        its pages (always the admit mask itself for the dense layout; the
        host only admits within the free-page budget, so a False is a
        scheduler bug and is asserted on the host side)."""
        spec, cfg = self.spec, self.cfg
        canvas = init_canvas(prompts, spec, cfg)
        tokens = jnp.where(admit[:, None], canvas, state.tokens)
        cache = C.reset(state.cache, admit)
        ok = admit
        if self.paged:
            cache, ok = C.alloc(cache, admit, 0,
                                spec.prompt_len + spec.block_size)
        out = forward(params, tokens[:, :spec.prompt_len], cfg=cfg,
                      mode=masks.BLOCK_CAUSAL, prompt_len=spec.full_prompt_len,
                      block_size=spec.block_size, attn_impl=spec.attn_impl,
                      return_logits=False)
        cache = C.commit_rows(cache, out.emissions, 0, admit)
        return state._replace(
            tokens=tokens, cache=cache,
            blk=jnp.where(admit, 0, state.blk),
            lane_nblocks=jnp.where(admit, nblocks, state.lane_nblocks),
            live=state.live | admit,
            steps=jnp.where(admit, 0, state.steps),
            calls=state.calls + 1), ok

    def _evict(self, state: _SlotState, rows) -> _SlotState:
        """Release lanes: mark dead and reset their cache (paged: return
        their pages to the pool)."""
        return state._replace(cache=C.reset(state.cache, rows),
                              live=state.live & ~rows)

    def _alloc_block(self, state: _SlotState):
        """Paged: ensure every live lane has pages for its current block.
        Returns ``(state, ok)``; a live lane with ``ok=False`` stalls this
        round (its table is untouched — all-or-nothing per lane)."""
        spec = self.spec
        P, B = spec.prompt_len, spec.block_size
        starts = P + jnp.clip(state.blk, 0, spec.n_blocks - 1) * B
        cache, ok = C.alloc(state.cache, state.live, starts, starts + B)
        return state._replace(cache=cache), ok

    def _decode_block(self, params, state: _SlotState, run) -> _SlotState:
        """Advance lanes selected by ``run`` by one block: threshold
        refinement to completion, then the exact commit pass into each
        lane's cache rows. Live lanes outside ``run`` (page-stalled) are
        left untouched and retry at the next boundary."""
        spec, cfg = self.spec, self.cfg
        P, B = spec.prompt_len, spec.block_size
        live = state.live & run
        starts = P + jnp.clip(state.blk, 0, spec.n_blocks - 1) * B

        def slice_blocks(tokens):
            return jax.vmap(
                lambda t, s: jax.lax.dynamic_slice(t, (s,), (B,)))(
                    tokens, starts)

        def scatter_blocks(tokens, blocks):
            return jax.vmap(
                lambda t, b, s: jax.lax.dynamic_update_slice(t, b, (s,)))(
                    tokens, blocks, starts)

        all_block = jnp.ones((1, B), bool)

        def cond(st):
            tokens, steps, calls, key, it = st
            bt = slice_blocks(tokens)
            act = jnp.any(bt == cfg.mask_token_id, axis=-1) & live
            return jnp.any(act) & (it < B)

        def body(st):
            tokens, steps, calls, key, it = st
            key, sub = jax.random.split(key)
            net, _ = lane_block_forward(
                params, tokens, starts, state.cache, cfg=cfg, spec=spec,
                use_long_window=self._use_long_window,
                paged_attention_fn=self._paged_attention_fn,
                return_hidden=self._fused)
            bt = slice_blocks(tokens)
            if self._fused:
                cand, conf = D.confidence_and_candidates_fused(
                    net, unembed_matrix(params, cfg), bt, cfg.mask_token_id,
                    spec.temperature, sub, softcap=cfg.final_logit_softcap)
            else:
                cand, conf = D.confidence_and_candidates(
                    net, bt, cfg.mask_token_id, spec.temperature, sub)
            sel = D.select_threshold_in_block(conf, all_block,
                                              spec.conf_threshold)
            active = jnp.any(bt == cfg.mask_token_id, axis=-1) & live
            sel = sel & active[:, None]
            bt = jnp.where(sel, cand.astype(bt.dtype), bt)
            return (scatter_blocks(tokens, bt),
                    steps + active.astype(jnp.int32), calls + 1, key, it + 1)

        tokens, steps, calls, key, _ = jax.lax.while_loop(
            cond, body,
            (state.tokens, state.steps, state.calls, state.key,
             jnp.zeros((), jnp.int32)))

        # commit pass: recompute the finalized blocks' KV exactly, only for
        # the lanes that ran, each at its own offset (only emissions are
        # consumed, so the lm_head is always skipped here)
        _, emissions = lane_block_forward(
            params, tokens, starts, state.cache, cfg=cfg, spec=spec,
            use_long_window=self._use_long_window,
            paged_attention_fn=self._paged_attention_fn, return_hidden=True)
        cache = C.commit_rows(state.cache, emissions, starts, live)
        calls = calls + 1

        bt = slice_blocks(tokens)
        eos_hit = jnp.any(bt == cfg.eos_token_id, axis=-1)
        blk = jnp.where(live, state.blk + 1, state.blk)
        finished = live & (eos_hit | (blk >= state.lane_nblocks))
        return state._replace(tokens=tokens, cache=cache, blk=blk,
                              live=state.live & ~finished, steps=steps,
                              calls=calls, key=key)

    # -- host-side scheduler -------------------------------------------------
    def warmup(self):
        state = self._init_state(jax.random.PRNGKey(0))
        N, P = self.n_lanes, self.spec.prompt_len
        state, _ = self._jit_admit(self.params, state,
                                   jnp.zeros((N, P), jnp.int32),
                                   jnp.ones((N,), bool),
                                   jnp.full((N,), self.spec.n_blocks,
                                            jnp.int32))
        run = jnp.ones((N,), bool)
        if self.paged:
            state, ok = self._jit_alloc_block(state)
            run = state.live & ok
            state = self._jit_evict(state, jnp.zeros((N,), bool))
        state = self._jit_decode_block(self.params, state, run)
        self._jit_gen_lengths(state.tokens).block_until_ready()
        self._warm = True

    def _lane_nblocks(self, req: Request) -> int:
        B = self.spec.block_size
        if req.max_tokens is None:
            return self.spec.n_blocks
        return max(1, min(self.spec.n_blocks, -(-req.max_tokens // B)))

    def generate(self, requests: Sequence[Request],
                 key=None) -> List[Response]:
        """Serve ``requests`` (honoring ``arrival_s`` offsets) and return
        responses in completion order."""
        if not requests:
            return []
        _validate_requests(requests)
        if requests[0].extras:
            raise ValueError("ContinuousEngine does not support request "
                             "extras (encoder/prefix embeds) yet")
        key = key if key is not None else jax.random.PRNGKey(0)
        N, P, B = self.n_lanes, self.spec.prompt_len, self.spec.block_size
        queue = deque(sorted(requests, key=lambda r: r.arrival_s))
        state = self._init_state(key)
        lane_req: List[Optional[Request]] = [None] * N
        lane_admit_t = np.zeros((N,), np.float64)
        out: List[Response] = []
        self._pool_samples = []
        self._live_samples = []
        self._preemptions = 0
        self._stall_rounds = 0
        t0 = time.perf_counter()

        while queue or any(r is not None for r in lane_req):
            now = time.perf_counter() - t0
            # ---- admission at the block boundary ----
            # paged: budgeted by free *pages* for prompt + next block, not by
            # whole-sequence reservation — a request enters as soon as its
            # next block can be backed
            free = [i for i in range(N) if lane_req[i] is None]
            free_pg = (int(np.asarray(C.free_page_count(state.cache)))
                       if self.paged and free and queue else 0)
            admit = np.zeros((N,), bool)
            prompts = np.zeros((N, P), np.int32)
            nblocks = np.zeros((N,), np.int32)
            for lane in free:
                if not queue or queue[0].arrival_s > now:
                    break
                if self.paged and free_pg < self._admit_pages:
                    break
                req = queue.popleft()
                lane_req[lane] = req
                lane_admit_t[lane] = now
                admit[lane] = True
                prompts[lane] = req.prompt
                nblocks[lane] = self._lane_nblocks(req)
                if self.paged:
                    free_pg -= self._admit_pages
            if admit.any():
                state, aok = self._jit_admit(self.params, state,
                                             jnp.asarray(prompts),
                                             jnp.asarray(admit),
                                             jnp.asarray(nblocks))
                if self.paged:
                    aok = np.asarray(aok)
                    assert bool(aok[admit].all()), \
                        "page accounting bug: admitted within budget but " \
                        "allocation failed"
            if not any(r is not None for r in lane_req):
                # nothing decoding and nothing arrived yet: idle to the next
                # arrival instead of spinning
                if queue:
                    wait = queue[0].arrival_s - (time.perf_counter() - t0)
                    if wait > 0:
                        time.sleep(wait)
                continue

            # ---- paged: back every live lane's current block with pages ----
            live = np.asarray(state.live)
            if self.paged:
                state, ok = self._jit_alloc_block(state)
                run = live & np.asarray(ok)
                while live.any() and not run.any():
                    # every live lane is page-starved: preempt the youngest
                    # (its pages go back to the pool, its request re-enters
                    # the queue — deterministic greedy decode makes the
                    # re-decode loss-free)
                    victims = [i for i in range(N) if live[i]]
                    victim = max(victims,
                                 key=lambda i: (lane_admit_t[i], i))
                    if len(victims) == 1:
                        raise RuntimeError(
                            "page pool exhausted with a single live lane — "
                            "pool sizing invariant violated")
                    vrow = np.zeros((N,), bool)
                    vrow[victim] = True
                    state = self._jit_evict(state, jnp.asarray(vrow))
                    queue.appendleft(lane_req[victim])
                    lane_req[victim] = None
                    self._preemptions += 1
                    live = np.asarray(state.live)
                    state, ok = self._jit_alloc_block(state)
                    run = live & np.asarray(ok)
                if not live.any():
                    continue
                if (live & ~run).any():
                    self._stall_rounds += 1
                self._pool_samples.append(
                    self.n_pages
                    - int(np.asarray(C.free_page_count(state.cache))))
            else:
                run = live

            # ---- one block-level decode step for the runnable lanes ----
            self._live_samples.append(int(run.sum()))
            state = self._jit_decode_block(self.params, state,
                                           jnp.asarray(run))
            live = np.asarray(state.live)
            t_done = time.perf_counter() - t0

            # ---- eviction of finished lanes ----
            done_lanes = [i for i in range(N)
                          if lane_req[i] is not None and not live[i]]
            if done_lanes:
                toks = np.asarray(state.tokens)
                steps = np.asarray(state.steps)
                glens = np.asarray(self._jit_gen_lengths(state.tokens))
                for lane in done_lanes:
                    req = lane_req[lane]
                    gen = toks[lane, P:]
                    glen = int(glens[lane])
                    if req.max_tokens is not None:
                        glen = min(glen, req.max_tokens)
                    out.append(Response(
                        id=req.id, tokens=gen, gen_length=glen,
                        steps=int(steps[lane]),
                        latency_s=t_done - req.arrival_s,
                        queue_s=lane_admit_t[lane] - req.arrival_s))
                    lane_req[lane] = None
                if self.paged:
                    # return the finished lanes' pages to the pool *now* so
                    # the next admission sees them
                    drow = np.zeros((N,), bool)
                    drow[done_lanes] = True
                    state = self._jit_evict(state, jnp.asarray(drow))
        return out

    def page_pool_stats(self) -> Dict[str, float]:
        """Occupancy report for the last :meth:`generate` run (paged layout;
        zeros for dense). Pages are sampled at every block boundary."""
        if not self.paged or not self._pool_samples:
            return {"n_pages": float(self.n_pages), "peak_pages": 0.0,
                    "avg_pages": 0.0, "peak_occupancy": 0.0,
                    "preemptions": 0.0, "stall_rounds": 0.0}
        peak = max(self._pool_samples)
        return {
            "n_pages": float(self.n_pages),
            "peak_pages": float(peak),
            "avg_pages": float(np.mean(self._pool_samples)),
            "peak_occupancy": peak / self.n_pages,
            "preemptions": float(self._preemptions),
            "stall_rounds": float(self._stall_rounds),
        }

    def concurrency_stats(self) -> Dict[str, float]:
        """Decoding-lane concurrency for the last :meth:`generate` run,
        sampled at every block-level decode step (both layouts)."""
        if not self._live_samples:
            return {"peak_lanes": 0.0, "avg_lanes": 0.0}
        return {"peak_lanes": float(max(self._live_samples)),
                "avg_lanes": float(np.mean(self._live_samples))}


def make_engine(params, cfg: ModelConfig, serve: ServeConfig,
                prompt_len: int, **kw):
    """Engine factory switched by ``serve.scheduler``."""
    if serve.scheduler == "continuous":
        if kw.pop("pos_offset", 0):
            raise ValueError("ContinuousEngine does not support prefix "
                             "embeds (pos_offset != 0) yet")
        return ContinuousEngine(params, cfg, serve, prompt_len, **kw)
    if serve.scheduler == "static":
        return Engine(params, cfg, serve, prompt_len, **kw)
    raise ValueError(f"unknown scheduler {serve.scheduler!r} "
                     "(expected 'static' or 'continuous')")


def efficiency_report(responses: Sequence[Response]) -> Dict[str, float]:
    """Per-sample averages, the paper's reporting convention (App. A.3)."""
    if not responses:
        return {"latency_s": 0.0, "steps": 0.0, "gen_length": 0.0, "tps": 0.0}
    lat = float(np.mean([r.latency_s for r in responses]))
    steps = float(np.mean([r.steps for r in responses]))
    glen = float(np.mean([r.gen_length for r in responses]))
    tps = glen / lat if lat > 0 else float("inf")
    return {"latency_s": lat, "steps": steps, "gen_length": glen, "tps": tps}
