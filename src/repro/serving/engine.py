"""Batched serving engines.

Two schedulers over the unified block-decode core
(``repro.core.block_loop``), both exposing the same **request-level,
incremental API** (types in ``repro.serving.api``):

- ``add_request(GenerationRequest) -> id`` — enqueue one request
  (engine-assigned unique id when ``id=None``);
- ``step() -> list[BlockEvent]`` — advance one block boundary and return
  the blocks that finalized this step (block-causal finalization means a
  returned block is committed and will never change — the natural exact
  streaming unit);
- ``abort(id)`` — drop a queued or in-flight request (freeing its lane
  and, in the paged layout, its pages) without perturbing other lanes;
- ``has_unfinished()`` — anything queued or decoding;
- ``stream(requests)`` — iterator yielding :class:`BlockEvent` as blocks
  commit;
- ``generate(requests)`` — thin drain-the-stepper wrapper returning final
  :class:`GenerationOutput` per request (bit-identical to the historical
  batch-synchronous behavior).

Sampling parameters are **per-request** (:class:`SamplingParams`):
temperature, confidence threshold, max_tokens, RNG seed and EOS override
all resolve against ``ServeConfig`` defaults and are threaded through the
decode loops as per-lane ``(b,)`` arrays
(:class:`repro.core.block_loop.LaneParams`), so one continuous batch can
mix greedy and sampled lanes. Sampled lanes draw with their *own* PRNG
stream (advanced only on the lane's own active iterations), which keeps
every lane bit-identical to its isolated decode regardless of batch
composition — the same isolation-exactness invariant the scheduler
already relied on for greedy lanes.

The two schedulers:

- :class:`Engine` — **static batching**: requests are padded into
  fixed-shape batches and each batch runs the full jitted sampler to
  completion. ``step()`` launches one batch and emits its block events at
  once.

- :class:`ContinuousEngine` — **continuous block-level batching**: a
  persistent decode batch of ``max_batch`` lanes advances one *block* per
  jitted step, each lane at its own block offset
  (:func:`repro.core.block_loop.lane_block_forward`). At every block
  boundary finished lanes are evicted, their cache rows reset
  (:func:`repro.core.cache.reset`), and queued requests admitted mid-flight
  (prompt prefill committed into the freed rows via ``commit_rows``).
  Block-causal cache exactness makes lane recycling loss-free, so a lane
  admitted mid-flight decodes bit-identically to one decoded in isolation.

The continuous engine runs over either KV layout
(``ServeConfig.cache_layout``):

- ``dense``: per-lane ``max_len`` KV rows — admission is slot-bound.
- ``paged``: a global page pool (page size = block size) with per-lane page
  tables (:class:`repro.core.cache.PagedCache`). Admission is *page*-bound:
  a request is admitted whenever pages for its prompt and next block exist
  (no whole-sequence reservation), each block boundary allocates just the
  pages the live lanes' next blocks need, and eviction returns a lane's
  pages to the pool. Lanes that cannot get their next page stall for a
  round; if every live lane stalls, the youngest lane is preempted (pages
  freed, request requeued — loss-free, since re-decoding from the
  request's own RNG stream is deterministic). A pool holding one full
  canvas is the deadlock-free minimum; sizing it below ``max_batch`` full
  canvases is what buys higher concurrency per HBM byte at mixed
  generation lengths.

  Paged scheduling is *sync-free and overlapped*: because the device
  allocator (:func:`repro.core.cache.alloc`) is deterministic — lanes
  scanned in index order, all-or-nothing per lane, pages handed out
  lowest-index-first — the host mirrors page accounting exactly (per-lane
  allocated-slot high-water mark + a free-page counter) and never blocks
  on device allocation results. Each ``step()`` dispatches
  in-flight-block alloc → admission → decode → *next-block prefetch*
  back-to-back; in-flight lanes get pages **before** admissions (a
  newcomer can't starve a running lane), and the prefetch claims the
  following block's pages while the current block's results drain, so the
  next boundary's alloc is a no-op. Page identity never feeds the decode
  math, so dense and paged decodes stay bit-identical.

Metrics follow the paper (Tables 1–2): per-request latency, TPS (valid
tokens / wall-clock), refinement steps, generation length. The continuous
engine reports true per-request latency (arrival → completion, queueing
included) instead of a per-chunk average.
"""
from __future__ import annotations

import bisect
import time
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core import cache as C
from repro.core import diffusion as D
from repro.core import masks
from repro.core.block_loop import (
    STRATEGIES,
    LaneParams,
    SamplerSpec,
    _gen_lengths,
    init_canvas,
    lane_block_forward,
    run_block_loop,
)
from repro.core.sampler import SAMPLERS
from repro.models import forward, unembed_matrix
from repro.serving.api import (
    BlockEvent,
    GenerationOutput,
    GenerationRequest,
    Request,  # noqa: F401  (re-exported legacy name)
    ResolvedSamplingParams,
    Response,  # noqa: F401  (re-exported legacy name)
    SamplingParams,
    normalize_requests,
)


def _validate_requests(requests: Sequence[GenerationRequest]) -> None:
    keys0 = frozenset(requests[0].extras or {})
    for r in requests:
        if frozenset(r.extras or {}) != keys0:
            raise ValueError(
                "all requests in a batch must carry the same extras keys: "
                f"request {requests[0].id} has {sorted(keys0)}, request "
                f"{r.id} has {sorted(r.extras or {})}")


def _resolve(req: GenerationRequest, serve: ServeConfig,
             cfg: ModelConfig) -> ResolvedSamplingParams:
    params = req.params if req.params is not None else SamplingParams()
    return params.resolve(serve, cfg, request_id=req.id,
                          legacy_max_tokens=req.max_tokens)


def _validate_params(req: GenerationRequest, serve: ServeConfig) -> None:
    """Per-request params constraints, checked at ``add_request`` time so
    a bad request fails its own submission (HTTP 400) instead of blowing
    up the shared decode step later.

    - Non-threshold samplers have no per-lane selection loop.
    - ``fused_select`` engines are greedy-only: a sampled lane in the
      batch would silently flip its greedy chunk-mates from the fused
      online-softmax kernel to the dense selection path, whose last-ULP
      confidence differences could break isolated-decode exactness.
    """
    if req.params is None or req.params.is_engine_default:
        return
    if STRATEGIES[serve.sampler].finalize != "threshold":
        raise ValueError(
            "per-request SamplingParams require a threshold-finalize "
            f"sampler; {serve.sampler!r} uses "
            f"{STRATEGIES[serve.sampler].finalize!r} (set the knobs "
            "globally in ServeConfig instead)")
    if serve.fused_select and (req.params.temperature or 0) > 0:
        raise ValueError(
            "fused_select engines serve greedy requests only "
            "(per-request temperature > 0 would mix fused and dense "
            "selection paths within one batch); disable fused_select to "
            "serve sampled requests")


def _lane_key(rp: ResolvedSamplingParams) -> np.ndarray:
    """A request's RNG stream root: ``PRNGKey(seed)`` — scheduler- and
    batch-invariant, so isolated and batched decodes draw identically."""
    return np.asarray(jax.random.PRNGKey(rp.seed), np.uint32)


def _finish_reason(gen: np.ndarray, glen_raw: int,
                   rp: ResolvedSamplingParams) -> str:
    """"stop" when the request's EOS token landed within its budget."""
    if not np.any(gen == rp.eos_token_id):
        return "length"
    if rp.max_tokens is not None and glen_raw > rp.max_tokens:
        return "length"
    return "stop"


class _RequestStepper:
    """Shared request-level surface of both engines: id/param validation at
    enqueue time, and the ``stream()``/``generate()`` drains over the
    engine-specific ``step()``."""

    def _register(self, request: GenerationRequest, taken) -> None:
        """Validate and id-assign one request at ``add_request`` time (so a
        bad request fails its own submission, not the shared decode step)."""
        _validate_params(request, self.serve)
        self._next_id = normalize_requests([request], self._next_id,
                                           taken=taken)
        if len(np.asarray(request.prompt)) != self.spec.prompt_len:
            raise ValueError(
                f"prompt length {len(np.asarray(request.prompt))} != engine "
                f"prompt_len {self.spec.prompt_len}")

    def stream(self, requests: Sequence[GenerationRequest], key=None):
        """Drain ``requests`` through the stepper, yielding a
        :class:`BlockEvent` the moment each block commits."""
        if not requests:
            return
        if self.has_unfinished():
            raise RuntimeError("engine busy: drain or abort in-flight "
                               "requests before a fresh stream()/generate()")
        _validate_requests(requests)
        self._reset(key)
        ids = [self.add_request(r) for r in requests]
        try:
            while self.has_unfinished():
                yield from self.step()
        finally:
            # early exit (break / generator GC): drop this call's
            # leftovers so the engine isn't wedged "busy" forever
            # (abort of already-completed ids is a no-op)
            if self.has_unfinished():
                for rid in ids:
                    self.abort(rid)

    def generate(self, requests: Sequence[GenerationRequest],
                 key=None) -> List[GenerationOutput]:
        """Thin drain-the-stepper wrapper returning the final outputs in
        completion order; bit-identical to the historical batch API."""
        return [ev.output for ev in self.stream(requests, key=key)
                if ev.finished]


class _Flight:
    """Host-side record of one in-flight request (continuous engine).
    ``arrival`` is the request's effective arrival offset: its trace
    ``arrival_s`` or, in incremental use, when ``add_request`` was called
    — so latency/queueing report arrival → completion, not engine-boot →
    completion."""
    __slots__ = ("req", "rp", "admit_t", "arrival", "blocks_done")

    def __init__(self, req: GenerationRequest, rp: ResolvedSamplingParams,
                 admit_t: float, arrival: float):
        self.req = req
        self.rp = rp
        self.admit_t = admit_t
        self.arrival = arrival
        self.blocks_done = 0


class Engine(_RequestStepper):
    """Static fixed-shape batching over any sampler strategy.

    The incremental API steps at *batch* granularity: ``step()`` pops up
    to ``max_batch`` queued requests, runs the jitted sampler to
    completion, and emits every block event of the batch at once.
    ``generate()`` drains the stepper and is bit-identical to the
    historical batch-synchronous behavior.
    """

    def __init__(self, params, cfg: ModelConfig, serve: ServeConfig,
                 prompt_len: int, *, pos_offset: int = 0,
                 use_long_window: bool = False):
        if serve.page_pool_pages is not None:
            raise ValueError(
                "page_pool_pages is only honored by the continuous "
                "scheduler with the paged layout; the static engine runs "
                "whole sequences to completion, so its paged pool is "
                "always sized dense-equivalent (batch x full canvas)")
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.spec = SamplerSpec(
            prompt_len=prompt_len, gen_len=serve.gen_length,
            block_size=serve.block_size, conf_threshold=serve.conf_threshold,
            temperature=serve.temperature,
            cache_refresh_interval=serve.cache_refresh_interval,
            pos_offset=pos_offset, cache_layout=serve.cache_layout,
            fused_select=serve.fused_select)
        self._use_long_window = use_long_window
        sampler = SAMPLERS[serve.sampler]
        kwargs = {}
        if serve.sampler == "cdlm" and use_long_window:
            kwargs["use_long_window"] = True

        def run(params, prompts, key, extras):
            return sampler(params, prompts, cfg=cfg, spec=self.spec, key=key,
                           extras=extras, **kwargs)

        self._run = jax.jit(run)
        self._lanes_jit: Dict[bool, Any] = {}
        self._warm = False
        self._next_id = 0
        self._reset()

    # -- incremental core ---------------------------------------------------
    def _reset(self, key=None) -> None:
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._queue: List[GenerationRequest] = []

    def add_request(self, request: GenerationRequest) -> int:
        """Enqueue one request; returns its (possibly engine-assigned) id."""
        self._register(request, {r.id for r in self._queue})
        self._queue.append(request)
        return request.id

    def has_unfinished(self) -> bool:
        return bool(self._queue)

    def abort(self, request_id: int) -> bool:
        """Drop a queued request (static batches run synchronously, so
        nothing is ever mid-flight between ``step()`` calls)."""
        for i, r in enumerate(self._queue):
            if r.id == request_id:
                del self._queue[i]
                return True
        return False

    def warmup(self, extras=None, *, per_request: bool = False):
        """Compile the scalar decode path; ``per_request=True`` (servers)
        also precompiles the per-lane-params variants so the first request
        carrying explicit :class:`SamplingParams` doesn't stall the
        serving loop on a jit compile."""
        b = self.serve.max_batch
        prompts = jnp.zeros((b, self.spec.prompt_len), jnp.int32)
        self._run(self.params, prompts, jax.random.PRNGKey(0),
                  extras or {}).tokens.block_until_ready()
        if (per_request
                and STRATEGIES[self.serve.sampler].finalize == "threshold"):
            lanes = LaneParams(
                temperature=jnp.zeros((b,), jnp.float32),
                conf_threshold=jnp.full((b,), self.serve.conf_threshold,
                                        jnp.float32),
                eos_id=jnp.full((b,), self.cfg.eos_token_id, jnp.int32),
                key=jnp.zeros((b, 2), jnp.uint32))
            self._lanes_runner(False)(
                self.params, prompts, lanes, extras or {}
            ).tokens.block_until_ready()
            if not self.serve.fused_select:  # sampled+fused is rejected
                self._lanes_runner(True)(
                    self.params, prompts, lanes, extras or {}
                ).tokens.block_until_ready()
        self._warm = True

    def _lanes_runner(self, sampled: bool):
        """Jitted per-lane-params variant of the sampler (two
        specializations: all-greedy lanes, and lanes that draw)."""
        if sampled not in self._lanes_jit:
            # only reachable for threshold-finalize samplers:
            # _validate_params rejects per-request params on others at
            # add_request time
            strategy = STRATEGIES[self.serve.sampler]

            def run(params, prompts, lanes, extras):
                return run_block_loop(
                    params, prompts, cfg=self.cfg, spec=self.spec,
                    strategy=strategy, extras=extras,
                    use_long_window=self._use_long_window,
                    lane_params=lanes, lane_sampled=sampled)

            self._lanes_jit[sampled] = jax.jit(run)
        return self._lanes_jit[sampled]

    def _n_emit_blocks(self, gen: np.ndarray,
                       rp: ResolvedSamplingParams) -> int:
        """Blocks to stream: through the first block containing the
        request's EOS (later blocks were early-stopped to [MASK] or are
        post-EOS filler), else up to the request's ``max_tokens`` cap
        (rounded up to a block), else the whole grid."""
        B = self.spec.block_size
        cap = self.spec.n_blocks
        if rp.max_tokens is not None:
            cap = max(1, min(cap, -(-rp.max_tokens // B)))
        hits = np.flatnonzero(gen == rp.eos_token_id)
        if hits.size:
            return min(int(hits[0]) // B + 1, cap)
        return cap

    def step(self) -> List[BlockEvent]:
        """Run one batch of up to ``max_batch`` queued requests to
        completion; returns every block event of the batch (final events
        carry the :class:`GenerationOutput`)."""
        if not self._queue:
            return []
        Bmax = self.serve.max_batch
        chunk = self._queue[:Bmax]
        _validate_requests(chunk)  # before consuming: a mismatched-extras
        del self._queue[:Bmax]     # chunk must not silently vanish
        rps = [_resolve(r, self.serve, self.cfg) for r in chunk]
        pad = Bmax - len(chunk)
        prompts = np.stack([np.asarray(r.prompt) for r in chunk]
                           + [np.asarray(chunk[-1].prompt)] * pad)
        extras = {}
        if chunk[0].extras:
            for k in chunk[0].extras:
                arrs = ([r.extras[k] for r in chunk]
                        + [chunk[-1].extras[k]] * pad)
                extras[k] = jnp.asarray(np.stack(arrs))
        self._key, sub = jax.random.split(self._key)
        # a chunk is one jit call, so any request with explicit params
        # moves the WHOLE chunk to the per-lane path. At temperature 0 the
        # two paths select identically; on a sampled-default engine
        # (ServeConfig.temperature > 0) this swaps bare chunk-mates from
        # the historical shared batch RNG stream to their own per-request
        # streams (PRNGKey(seed or id)) — batch-composition-independent,
        # but different draws than an all-bare chunk.
        use_lanes = any(r.params is not None
                        and not r.params.is_engine_default for r in chunk)
        t0 = time.perf_counter()
        if use_lanes:
            prps = rps + [rps[-1]] * pad
            lanes = LaneParams(
                temperature=jnp.asarray([p.temperature for p in prps],
                                        jnp.float32),
                conf_threshold=jnp.asarray([p.conf_threshold for p in prps],
                                           jnp.float32),
                eos_id=jnp.asarray([p.eos_token_id for p in prps],
                                   jnp.int32),
                key=jnp.asarray(np.stack([_lane_key(p) for p in prps])))
            sampled = any(p.temperature > 0 for p in prps)
            res = self._lanes_runner(sampled)(
                self.params, jnp.asarray(prompts), lanes, extras)
        else:
            res = self._run(self.params, jnp.asarray(prompts), sub, extras)
        res.tokens.block_until_ready()
        dt = (time.perf_counter() - t0) / len(chunk)
        toks = np.asarray(res.tokens)
        steps = np.asarray(res.steps)
        glens = np.asarray(res.gen_lengths)
        P, B = self.spec.prompt_len, self.spec.block_size
        events: List[BlockEvent] = []
        for j, (r, rp) in enumerate(zip(chunk, rps)):
            gen = toks[j, P:]
            glen_raw = int(glens[j])
            # reason is judged on the untrimmed span (same rule as the
            # continuous engine: EOS landing exactly on the cap is "stop")
            reason = _finish_reason(gen, glen_raw, rp)
            glen = glen_raw
            if rp.max_tokens is not None:
                glen = min(glen, rp.max_tokens)
                gen = gen[:rp.max_tokens]
            out = GenerationOutput(
                id=r.id, tokens=gen, gen_length=glen, steps=int(steps[j]),
                latency_s=dt, finish_reason=reason)
            n_blocks = self._n_emit_blocks(gen, rp)
            for blk in range(n_blocks):
                events.append(BlockEvent(
                    request_id=r.id, index=blk, start=blk * B,
                    tokens=toks[j, P + blk * B:P + (blk + 1) * B].copy(),
                    finished=(blk == n_blocks - 1),
                    output=out if blk == n_blocks - 1 else None))
        return events


# ---------------------------------------------------------------------------
# Continuous block-level batching
# ---------------------------------------------------------------------------
class _SlotState(NamedTuple):
    tokens: jnp.ndarray       # (N, P+G) canvases
    cache: Any                # batch KV cache, lanes on axis 1
    blk: jnp.ndarray          # (N,) int32 — each lane's current block index
    lane_nblocks: jnp.ndarray  # (N,) int32 — blocks this request decodes
    live: jnp.ndarray         # (N,) bool — lane occupied and unfinished
    steps: jnp.ndarray        # (N,) int32 refinement iterations
    calls: jnp.ndarray        # () int32 total forward passes
    temps: jnp.ndarray        # (N,) float32 per-lane temperature
    taus: jnp.ndarray         # (N,) float32 per-lane conf threshold
    eos: jnp.ndarray          # (N,) int32 per-lane EOS token
    keys: jnp.ndarray         # (N, 2) uint32 per-lane PRNG keys


class ContinuousEngine(_RequestStepper):
    """Slot-based continuous batching over the CDLM exact-cache strategy.

    Scheduling happens at block boundaries: each jitted ``_decode_block``
    call advances every live lane by one block (threshold refinement +
    commit pass); between calls the host evicts finished lanes and admits
    arrived requests into the freed slots — that boundary is exactly one
    ``step()`` of the incremental API, and the blocks finalized by it are
    the returned :class:`BlockEvent` stream. Only the ``cdlm`` strategy is
    supported — approximate-cache strategies refresh KV from the *whole*
    canvas, which couples lanes to batch-global state, and only the exact
    block-causal cache makes per-lane recycling loss-free.

    Per-request sampling: each lane carries its own temperature, τ, EOS
    and PRNG key (``_SlotState.temps/taus/eos/keys``). Greedy and sampled
    lanes mix freely; a sampled lane's key advances only on its own active
    refinement iterations, so its draws are independent of batch
    composition and bit-identical to its isolated decode.
    """

    def __init__(self, params, cfg: ModelConfig, serve: ServeConfig,
                 prompt_len: int, *, use_long_window: bool = False,
                 use_paged_kernel: bool = False):
        if serve.sampler != "cdlm":
            raise ValueError(
                "ContinuousEngine requires the 'cdlm' strategy (exact "
                f"block-causal cache); got sampler={serve.sampler!r}")
        if use_paged_kernel and serve.cache_layout != C.PAGED:
            raise ValueError("use_paged_kernel requires cache_layout='paged'")
        if cfg.is_encoder_decoder:
            raise ValueError("ContinuousEngine does not support "
                             "encoder-decoder models yet (per-lane encoder "
                             "state is not scheduled)")
        if serve.cache_layout not in C.CACHE_LAYOUTS:
            raise ValueError(f"unknown cache layout {serve.cache_layout!r} "
                             f"(expected one of {C.CACHE_LAYOUTS})")
        if (serve.cache_layout != C.PAGED
                and serve.page_pool_pages is not None):
            raise ValueError("page_pool_pages requires cache_layout='paged' "
                             "— the dense layout preallocates per-lane "
                             "buffers and would silently ignore the budget")
        if serve.fused_select and serve.temperature > 0:
            raise ValueError(
                "fused_select is greedy-only: a sampled default "
                "(temperature > 0) would route every step through the "
                "dense selection path, mixing fused and dense decodes "
                "across batch compositions")
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.spec = SamplerSpec(
            prompt_len=prompt_len, gen_len=serve.gen_length,
            block_size=serve.block_size, conf_threshold=serve.conf_threshold,
            temperature=serve.temperature, early_stop=True,
            cache_layout=serve.cache_layout, fused_select=serve.fused_select)
        # fused unembed+select decode: lane forwards skip the lm_head and
        # candidates/confidences come from the vocab-tiled selection kernel
        # — no (b, B, V) logits in the refinement loop. Engages only on
        # all-greedy steps; a step with any sampled lane needs logits.
        self._fused = serve.fused_select
        self.n_lanes = serve.max_batch
        self.paged = serve.cache_layout == C.PAGED
        P, B = prompt_len, serve.block_size
        T = prompt_len + serve.gen_length
        if self.paged:
            self._n_tables = -(-T // B)
            self.n_pages = (serve.page_pool_pages
                            if serve.page_pool_pages is not None
                            else self.n_lanes * self._n_tables)
            if self.n_pages < self._n_tables:
                raise ValueError(
                    f"page pool of {self.n_pages} pages cannot back one "
                    f"full request ({self._n_tables} pages of {B} tokens "
                    f"for prompt {P} + gen {serve.gen_length}) — this is "
                    "the deadlock-free minimum")
            # pages a fresh request needs at admission: prompt + first block
            self._admit_pages = C.pages_for_span(0, P + B, B)
        else:
            self.n_pages = 0
        self._use_long_window = use_long_window
        # opt-in Pallas flash-decode over the page table (TPU hot path;
        # interpret-mode on CPU — numerically equal to the gather path to
        # fp32 tolerance, not bit-equal, since reduction order differs)
        self._paged_attention_fn = None
        if use_paged_kernel:
            from repro.kernels.decode_attn import paged_decode_attention
            self._paged_attention_fn = paged_decode_attention
        self._jit_admit = jax.jit(self._admit)
        self._jit_decode_block = jax.jit(self._decode_block,
                                         static_argnames=("sampled",))
        self._jit_evict = jax.jit(self._evict)
        self._jit_alloc_block = jax.jit(self._alloc_block)
        self._jit_gen_lengths = jax.jit(
            lambda tokens, eos: _gen_lengths(tokens, self.spec, self.cfg,
                                             eos_id=eos))
        self._warm = False
        self._next_id = 0
        self._reset()

    # -- jitted state transitions -------------------------------------------
    def _init_state(self) -> _SlotState:
        N = self.n_lanes
        T = self.spec.prompt_len + self.spec.gen_len
        if self.paged:
            cache = C.init_paged_cache(
                self.cfg, N, self._n_tables * self.spec.block_size,
                n_pages=self.n_pages, page_size=self.spec.block_size,
                dtype=self.cfg.dtype)
        else:
            cache = C.init_cache(self.cfg, N, T, dtype=self.cfg.dtype)
        return _SlotState(
            tokens=jnp.full((N, T), self.cfg.mask_token_id, jnp.int32),
            cache=cache,
            blk=jnp.zeros((N,), jnp.int32),
            lane_nblocks=jnp.full((N,), self.spec.n_blocks, jnp.int32),
            live=jnp.zeros((N,), bool),
            steps=jnp.zeros((N,), jnp.int32),
            calls=jnp.zeros((), jnp.int32),
            temps=jnp.zeros((N,), jnp.float32),
            taus=jnp.full((N,), self.spec.conf_threshold, jnp.float32),
            eos=jnp.full((N,), self.cfg.eos_token_id, jnp.int32),
            keys=jnp.zeros((N, 2), jnp.uint32))

    def _admit(self, params, state: _SlotState, prompts, admit, nblocks,
               temps, taus, eos, keys):
        """Admit requests into freed lanes: write canvases and per-lane
        sampling params, reset cache rows (paged: allocate prompt +
        first-block pages), prefill prompts under the block-causal mask,
        commit into those rows.

        Returns ``(state, ok)`` — ``ok`` is the admitted-lane mask that got
        its pages (always the admit mask itself for the dense layout; the
        host only admits within the free-page budget, so a False is a
        scheduler bug and is asserted on the host side)."""
        spec, cfg = self.spec, self.cfg
        canvas = init_canvas(prompts, spec, cfg)
        tokens = jnp.where(admit[:, None], canvas, state.tokens)
        cache = C.reset(state.cache, admit)
        ok = admit
        if self.paged:
            cache, ok = C.alloc(cache, admit, 0,
                                spec.prompt_len + spec.block_size)
        out = forward(params, tokens[:, :spec.prompt_len], cfg=cfg,
                      mode=masks.BLOCK_CAUSAL, prompt_len=spec.full_prompt_len,
                      block_size=spec.block_size, attn_impl=spec.attn_impl,
                      return_logits=False)
        cache = C.commit_rows(cache, out.emissions, 0, admit)
        return state._replace(
            tokens=tokens, cache=cache,
            blk=jnp.where(admit, 0, state.blk),
            lane_nblocks=jnp.where(admit, nblocks, state.lane_nblocks),
            live=state.live | admit,
            steps=jnp.where(admit, 0, state.steps),
            calls=state.calls + 1,
            temps=jnp.where(admit, temps, state.temps),
            taus=jnp.where(admit, taus, state.taus),
            eos=jnp.where(admit, eos, state.eos),
            keys=jnp.where(admit[:, None], keys, state.keys)), ok

    def _evict(self, state: _SlotState, rows) -> _SlotState:
        """Release lanes: mark dead and reset their cache (paged: return
        their pages to the pool)."""
        return state._replace(cache=C.reset(state.cache, rows),
                              live=state.live & ~rows)

    def _alloc_block(self, state: _SlotState):
        """Paged: ensure every live lane has pages for its current block.
        Returns ``(state, ok)``; a live lane with ``ok=False`` stalls this
        round (its table is untouched — all-or-nothing per lane)."""
        spec = self.spec
        P, B = spec.prompt_len, spec.block_size
        starts = P + jnp.clip(state.blk, 0, spec.n_blocks - 1) * B
        cache, ok = C.alloc(state.cache, state.live, starts, starts + B)
        return state._replace(cache=cache), ok

    def _decode_block(self, params, state: _SlotState, run, *,
                      sampled: bool) -> _SlotState:
        """Advance lanes selected by ``run`` by one block: threshold
        refinement to completion, then the exact commit pass into each
        lane's cache rows. Live lanes outside ``run`` (page-stalled) are
        left untouched and retry at the next boundary.

        ``sampled`` (static) is True when any lane in the batch draws
        categorically: the refinement forwards then carry logits and
        per-lane keys advance for active lanes. All-greedy steps keep the
        (optionally fused, lm_head-free) greedy path bit-for-bit."""
        spec, cfg = self.spec, self.cfg
        P, B = spec.prompt_len, spec.block_size
        live = state.live & run
        starts = P + jnp.clip(state.blk, 0, spec.n_blocks - 1) * B
        fused = self._fused and not sampled

        def slice_blocks(tokens):
            return jax.vmap(
                lambda t, s: jax.lax.dynamic_slice(t, (s,), (B,)))(
                    tokens, starts)

        def scatter_blocks(tokens, blocks):
            return jax.vmap(
                lambda t, b, s: jax.lax.dynamic_update_slice(t, b, (s,)))(
                    tokens, blocks, starts)

        all_block = jnp.ones((1, B), bool)

        def cond(st):
            tokens, steps, calls, keys, it = st
            bt = slice_blocks(tokens)
            act = jnp.any(bt == cfg.mask_token_id, axis=-1) & live
            return jnp.any(act) & (it < B)

        def body(st):
            tokens, steps, calls, keys, it = st
            bt = slice_blocks(tokens)
            active = jnp.any(bt == cfg.mask_token_id, axis=-1) & live
            if sampled:
                keys, subs = D.split_lane_keys(keys, active)
            net, _ = lane_block_forward(
                params, tokens, starts, state.cache, cfg=cfg, spec=spec,
                use_long_window=self._use_long_window,
                paged_attention_fn=self._paged_attention_fn,
                return_hidden=fused)
            if fused:
                cand, conf = D.confidence_and_candidates_fused(
                    net, unembed_matrix(params, cfg), bt, cfg.mask_token_id,
                    0.0, None, softcap=cfg.final_logit_softcap)
            elif sampled:
                cand, conf = D.confidence_and_candidates_per_lane(
                    net, bt, cfg.mask_token_id, state.temps, subs)
            else:
                cand, conf = D.confidence_and_candidates(
                    net, bt, cfg.mask_token_id, 0.0, None)
            sel = D.select_threshold_in_block(conf, all_block,
                                              state.taus[:, None])
            sel = sel & active[:, None]
            bt = jnp.where(sel, cand.astype(bt.dtype), bt)
            return (scatter_blocks(tokens, bt),
                    steps + active.astype(jnp.int32), calls + 1, keys, it + 1)

        tokens, steps, calls, keys, _ = jax.lax.while_loop(
            cond, body,
            (state.tokens, state.steps, state.calls, state.keys,
             jnp.zeros((), jnp.int32)))

        # commit pass: recompute the finalized blocks' KV exactly, only for
        # the lanes that ran, each at its own offset (only emissions are
        # consumed, so the lm_head is always skipped here)
        _, emissions = lane_block_forward(
            params, tokens, starts, state.cache, cfg=cfg, spec=spec,
            use_long_window=self._use_long_window,
            paged_attention_fn=self._paged_attention_fn, return_hidden=True)
        cache = C.commit_rows(state.cache, emissions, starts, live)
        calls = calls + 1

        bt = slice_blocks(tokens)
        eos_hit = jnp.any(bt == state.eos[:, None], axis=-1)
        blk = jnp.where(live, state.blk + 1, state.blk)
        finished = live & (eos_hit | (blk >= state.lane_nblocks))
        return state._replace(tokens=tokens, cache=cache, blk=blk,
                              live=state.live & ~finished, steps=steps,
                              calls=calls, keys=keys)

    # -- host-side scheduler -------------------------------------------------
    def _reset(self, key=None) -> None:
        del key  # per-request RNG streams derive from SamplingParams.seed
        self._state = self._init_state()
        self._queue: List[GenerationRequest] = []
        self._flights: List[Optional[_Flight]] = [None] * self.n_lanes
        self._resolved: Dict[int, ResolvedSamplingParams] = {}
        # effective arrival offset per request id (trace arrival_s, or the
        # add_request() wall-clock offset in incremental/server use)
        self._arrival: Dict[int, float] = {}
        # blocks already streamed per request id: a preempted request
        # re-decodes from scratch (bit-identically), but its re-decoded
        # blocks must not be re-emitted to stream consumers
        self._emitted: Dict[int, int] = {}
        self._t0 = time.perf_counter()
        self._pool_samples: List[int] = []
        self._live_samples: List[int] = []
        self._preemptions = 0
        self._stall_rounds = 0
        # host mirror of the device page allocator (paged layout): per-lane
        # allocated table-slot high-water mark and the pool's free count.
        # cache.alloc is deterministic (lane-index order, all-or-nothing,
        # lowest-index-first pages) and every span this engine allocates is
        # a contiguous slot prefix, so (hi, free) reproduce its decisions
        # exactly — step() never reads an allocation result off the device.
        self._host_hi = np.zeros((self.n_lanes,), np.int64)
        self._host_blk = np.zeros((self.n_lanes,), np.int64)
        self._host_free = self.n_pages

    # -- host page-accounting mirror (paged layout) --------------------------
    def _host_target_hi(self, blk: int) -> int:
        """Table slots a lane must hold through block ``blk``:
        ceil(P/B) prompt slots + blk+1 block slots (spans are contiguous
        slot prefixes, so this is the whole allocation state)."""
        P, B = self.spec.prompt_len, self.spec.block_size
        return -(-P // B) + min(int(blk), self.spec.n_blocks - 1) + 1

    def _host_alloc(self, rows: np.ndarray) -> np.ndarray:
        """Mirror ``cache.alloc`` for ``rows``'s current blocks: lane-index
        order, all-or-nothing per lane. Returns the per-lane ok mask and
        commits successful lanes to the mirror."""
        ok = np.zeros((self.n_lanes,), bool)
        for i in range(self.n_lanes):
            if not rows[i]:
                continue
            t = self._host_target_hi(self._host_blk[i])
            need = max(0, t - int(self._host_hi[i]))
            if need <= self._host_free:
                self._host_free -= need
                self._host_hi[i] = max(int(self._host_hi[i]), t)
                ok[i] = True
        return ok

    def _host_evict(self, rows: np.ndarray) -> None:
        """Mirror ``cache.reset``: a lane's pages all return to the pool."""
        for i in np.flatnonzero(rows):
            self._host_free += int(self._host_hi[i])
            self._host_hi[i] = 0

    def page_accounting(self):
        """(host_free, device_free) — equal by construction; the device
        read exists for tests/debugging only (it synchronizes)."""
        dev = int(np.asarray(C.free_page_count(self._state.cache)))
        return self._host_free, dev

    def warmup(self, extras=None, *, per_request: bool = False):
        """Compile the admit/decode/evict paths; ``per_request=True``
        (servers) also precompiles the sampled decode variant — see
        :meth:`Engine.warmup`."""
        if extras:
            raise ValueError("ContinuousEngine does not support request "
                             "extras (encoder/prefix embeds) yet")
        state = self._init_state()
        N, P = self.n_lanes, self.spec.prompt_len
        state, _ = self._jit_admit(
            self.params, state, jnp.zeros((N, P), jnp.int32),
            jnp.ones((N,), bool),
            jnp.full((N,), self.spec.n_blocks, jnp.int32),
            state.temps, state.taus, state.eos, state.keys)
        run = jnp.ones((N,), bool)
        if self.paged:
            state, ok = self._jit_alloc_block(state)
            run = state.live & ok
            state = self._jit_evict(state, jnp.zeros((N,), bool))
        state = self._jit_decode_block(self.params, state, run,
                                       sampled=False)
        if (self.serve.temperature > 0
                or (per_request and not self._fused)):
            # precompile the sampled decode variant: the engine default
            # makes every lane sampled, or (servers) any request may carry
            # temperature > 0 and compiling lazily would stall the serving
            # loop on the first sampled request
            self._jit_decode_block(self.params, state, run, sampled=True)
        self._jit_gen_lengths(state.tokens, state.eos).block_until_ready()
        self._warm = True

    def _lane_nblocks(self, rp: ResolvedSamplingParams) -> int:
        B = self.spec.block_size
        if rp.max_tokens is None:
            return self.spec.n_blocks
        return max(1, min(self.spec.n_blocks, -(-rp.max_tokens // B)))

    # -- incremental core ---------------------------------------------------
    def add_request(self, request: GenerationRequest) -> int:
        """Enqueue one request (admitted at the next block boundary with a
        free lane / enough free pages); returns its unique id."""
        if request.extras:
            raise ValueError("ContinuousEngine does not support request "
                             "extras (encoder/prefix embeds) yet")
        self._register(request,
                       {r.id for r in self._queue}
                       | {f.req.id for f in self._flights if f is not None})
        self._resolved[request.id] = _resolve(request, self.serve, self.cfg)
        self._arrival[request.id] = max(request.arrival_s,
                                        time.perf_counter() - self._t0)
        # stable arrival-order insertion (insort keeps FIFO among equal
        # arrival_s); requeued preemption victims sit at the front by
        # construction (direct insert(0) in step())
        bisect.insort(self._queue, request, key=lambda r: r.arrival_s)
        return request.id

    def has_unfinished(self) -> bool:
        return bool(self._queue) or any(f is not None for f in self._flights)

    def abort(self, request_id: int) -> bool:
        """Drop a queued or in-flight request. An in-flight lane is evicted
        at once — its cache rows reset and (paged) its pages returned to
        the pool — without touching any other lane."""
        for i, r in enumerate(self._queue):
            if r.id == request_id:
                del self._queue[i]
                self._resolved.pop(request_id, None)
                self._emitted.pop(request_id, None)
                self._arrival.pop(request_id, None)
                return True
        for lane, fl in enumerate(self._flights):
            if fl is not None and fl.req.id == request_id:
                row = np.zeros((self.n_lanes,), bool)
                row[lane] = True
                self._state = self._jit_evict(self._state, jnp.asarray(row))
                if self.paged:
                    self._host_evict(row)
                self._flights[lane] = None
                self._resolved.pop(request_id, None)
                self._emitted.pop(request_id, None)
                self._arrival.pop(request_id, None)
                return True
        return False

    def _sampled_step(self) -> bool:
        return any(f is not None and f.rp.temperature > 0
                   for f in self._flights)

    def step(self) -> List[BlockEvent]:
        """Advance one block boundary: (paged) back the in-flight lanes'
        current blocks with pages, admit arrived requests into free lanes,
        run one block-level decode for the runnable lanes, (paged) prefetch
        the survivors' *next* blocks, evict finished lanes. Returns one
        :class:`BlockEvent` per block finalized this step (final blocks
        carry the request's :class:`GenerationOutput`).

        The paged path is dispatch-only up to the decode: the run mask and
        the admission budget come from the host page mirror, so no device
        allocation result is ever read back. In-flight lanes allocate
        before admissions (newcomers can't starve a running lane), and most
        boundaries find their pages already claimed by the previous step's
        prefetch.
        """
        N, P, B = self.n_lanes, self.spec.prompt_len, self.spec.block_size
        state = self._state
        now = time.perf_counter() - self._t0

        # ---- paged: back the in-flight lanes' current blocks FIRST ----
        live = np.asarray([f is not None for f in self._flights])
        run = np.zeros((N,), bool)
        if self.paged and live.any():
            run = self._host_alloc(live)
            while not run.any():
                # every live lane is page-starved: preempt the youngest
                # (its pages go back to the pool, its request re-enters
                # the queue — the request's own deterministic RNG stream
                # makes the re-decode loss-free)
                victims = [i for i in range(N) if live[i]]
                victim = max(victims,
                             key=lambda i: (self._flights[i].admit_t, i))
                if len(victims) == 1:
                    raise RuntimeError(
                        "page pool exhausted with a single live lane — "
                        "pool sizing invariant violated")
                vrow = np.zeros((N,), bool)
                vrow[victim] = True
                state = self._jit_evict(state, jnp.asarray(vrow))
                self._host_evict(vrow)
                self._queue.insert(0, self._flights[victim].req)
                self._flights[victim] = None
                self._preemptions += 1
                live[victim] = False
                run = self._host_alloc(live)
            # one dispatch, no result read: the device allocator's
            # decisions equal the host plan by construction
            state, _ = self._jit_alloc_block(state)
            if (live & ~run).any():
                self._stall_rounds += 1
        elif not self.paged:
            run = live.copy()

        # ---- admission at the block boundary ----
        # paged: budgeted by the mirror's free *pages* for prompt + next
        # block, not by whole-sequence reservation — a request enters as
        # soon as its next block can be backed
        free = [i for i in range(N) if self._flights[i] is None]
        admit = np.zeros((N,), bool)
        prompts = np.zeros((N, P), np.int32)
        nblocks = np.zeros((N,), np.int32)
        temps = np.zeros((N,), np.float32)
        taus = np.zeros((N,), np.float32)
        eos = np.zeros((N,), np.int32)
        keys = np.zeros((N, 2), np.uint32)
        for lane in free:
            if not self._queue or self._queue[0].arrival_s > now:
                break
            if self.paged and self._host_free < self._admit_pages:
                break
            req = self._queue.pop(0)
            rp = self._resolved[req.id]
            self._flights[lane] = _Flight(
                req, rp, admit_t=now,
                arrival=self._arrival.get(req.id, req.arrival_s))
            admit[lane] = True
            prompts[lane] = np.asarray(req.prompt)
            nblocks[lane] = self._lane_nblocks(rp)
            temps[lane] = rp.temperature
            taus[lane] = rp.conf_threshold
            eos[lane] = rp.eos_token_id
            keys[lane] = _lane_key(rp)
            if self.paged:
                self._host_free -= self._admit_pages
                self._host_hi[lane] = self._admit_pages
                self._host_blk[lane] = 0
        if admit.any():
            state, _ = self._jit_admit(
                self.params, state, jnp.asarray(prompts), jnp.asarray(admit),
                jnp.asarray(nblocks), jnp.asarray(temps), jnp.asarray(taus),
                jnp.asarray(eos), jnp.asarray(keys))
            run = run | admit
        if all(f is None for f in self._flights):
            # nothing decoding and nothing arrived yet: idle to the next
            # arrival instead of spinning
            self._state = state
            if self._queue:
                wait = self._queue[0].arrival_s - (time.perf_counter()
                                                   - self._t0)
                if wait > 0:
                    time.sleep(wait)
            return []
        if self.paged:
            self._pool_samples.append(self.n_pages - self._host_free)

        # ---- one block-level decode step for the runnable lanes ----
        self._live_samples.append(int(run.sum()))
        self._host_blk[run] += 1
        state = self._jit_decode_block(self.params, state, jnp.asarray(run),
                                       sampled=self._sampled_step())
        if self.paged:
            # prefetch: claim the surviving lanes' next-block pages while
            # this boundary's results drain — dispatched before any result
            # is read, so the next step's in-flight alloc is a no-op
            state, _ = self._jit_alloc_block(state)
        live = np.asarray(state.live)
        if self.paged:
            self._host_alloc(live)
        t_done = time.perf_counter() - self._t0

        # ---- block events + eviction of finished lanes ----
        ran = [i for i in range(N)
               if run[i] and self._flights[i] is not None]
        events: List[BlockEvent] = []
        done_lanes = [i for i in ran if not live[i]]
        toks = steps_arr = glens = None
        if done_lanes:
            # full-canvas transfer only when a request completed (the
            # legacy cadence); in-flight boundaries move one block per
            # ran lane below
            toks = np.asarray(state.tokens)
            steps_arr = np.asarray(state.steps)
            glens = np.asarray(self._jit_gen_lengths(state.tokens,
                                                     state.eos))
        for lane in ran:
            fl = self._flights[lane]
            blk = fl.blocks_done
            fl.blocks_done += 1
            if live[lane] and blk < self._emitted.get(fl.req.id, 0):
                continue  # preemption re-decode: block already streamed
            self._emitted[fl.req.id] = blk + 1
            lo, hi = P + blk * B, P + (blk + 1) * B
            block_toks = (toks[lane, lo:hi].copy() if toks is not None
                          else np.asarray(state.tokens[lane, lo:hi]))
            ev = BlockEvent(
                request_id=fl.req.id, index=blk, start=blk * B,
                tokens=block_toks, finished=not live[lane])
            if ev.finished:
                gen = toks[lane, P:].copy()
                glen_raw = int(glens[lane])
                # reason judged on the untrimmed span; the returned span
                # is sliced to the cap (same contract as the static
                # engine — no [MASK] filler past max_tokens)
                reason = _finish_reason(gen, glen_raw, fl.rp)
                glen = glen_raw
                if fl.rp.max_tokens is not None:
                    glen = min(glen, fl.rp.max_tokens)
                    gen = gen[:fl.rp.max_tokens]
                ev.output = GenerationOutput(
                    id=fl.req.id, tokens=gen, gen_length=glen,
                    steps=int(steps_arr[lane]),
                    latency_s=t_done - fl.arrival,
                    queue_s=fl.admit_t - fl.arrival,
                    finish_reason=reason)
                self._flights[lane] = None
                self._resolved.pop(fl.req.id, None)
                self._emitted.pop(fl.req.id, None)
                self._arrival.pop(fl.req.id, None)
            events.append(ev)
        if done_lanes and self.paged:
            # return the finished lanes' pages to the pool *now* so the
            # next admission sees them
            drow = np.zeros((N,), bool)
            drow[done_lanes] = True
            state = self._jit_evict(state, jnp.asarray(drow))
            self._host_evict(drow)
        self._state = state
        return events

    def page_pool_stats(self) -> Dict[str, float]:
        """Occupancy report since the last reset (paged layout; zeros for
        dense). Pages are sampled at every block boundary."""
        if not self.paged or not self._pool_samples:
            return {"n_pages": float(self.n_pages), "peak_pages": 0.0,
                    "avg_pages": 0.0, "peak_occupancy": 0.0,
                    "preemptions": 0.0, "stall_rounds": 0.0}
        peak = max(self._pool_samples)
        return {
            "n_pages": float(self.n_pages),
            "peak_pages": float(peak),
            "avg_pages": float(np.mean(self._pool_samples)),
            "peak_occupancy": peak / self.n_pages,
            "preemptions": float(self._preemptions),
            "stall_rounds": float(self._stall_rounds),
        }

    def concurrency_stats(self) -> Dict[str, float]:
        """Decoding-lane concurrency since the last reset, sampled at every
        block-level decode step (both layouts)."""
        if not self._live_samples:
            return {"peak_lanes": 0.0, "avg_lanes": 0.0}
        return {"peak_lanes": float(max(self._live_samples)),
                "avg_lanes": float(np.mean(self._live_samples))}


def make_engine(params, cfg: ModelConfig, serve: ServeConfig,
                prompt_len: int, **kw):
    """Engine factory switched by ``serve.scheduler``."""
    if serve.scheduler == "continuous":
        if kw.pop("pos_offset", 0):
            raise ValueError("ContinuousEngine does not support prefix "
                             "embeds (pos_offset != 0) yet")
        return ContinuousEngine(params, cfg, serve, prompt_len, **kw)
    if serve.scheduler == "static":
        return Engine(params, cfg, serve, prompt_len, **kw)
    raise ValueError(f"unknown scheduler {serve.scheduler!r} "
                     "(expected 'static' or 'continuous')")


def efficiency_report(responses: Sequence[GenerationOutput]) -> Dict[str, float]:
    """Per-sample averages, the paper's reporting convention (App. A.3)."""
    if not responses:
        return {"latency_s": 0.0, "steps": 0.0, "gen_length": 0.0, "tps": 0.0}
    lat = float(np.mean([r.latency_s for r in responses]))
    steps = float(np.mean([r.steps for r in responses]))
    glen = float(np.mean([r.gen_length for r in responses]))
    tps = glen / lat if lat > 0 else float("inf")
    return {"latency_s": lat, "steps": steps, "gen_length": glen, "tps": tps}
