"""Batched serving engine.

Wraps a model + sampler into a request/response loop with the paper's
efficiency metrics: per-sample latency, TPS (valid tokens / wall-clock),
refinement steps, generation length — the exact columns of Tables 1–2.
Requests are padded into fixed-shape batches (static shapes keep the jitted
sampler cache warm); per-sequence early stopping happens inside the sampler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.core.sampler import SAMPLERS, SamplerSpec


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                       # (P,) int32
    extras: Optional[Dict[str, np.ndarray]] = None
    id: int = 0


@dataclasses.dataclass
class Response:
    id: int
    tokens: np.ndarray                       # generated span (gen_len,)
    gen_length: int
    steps: int
    latency_s: float                         # per-sample share of batch time


class Engine:
    def __init__(self, params, cfg: ModelConfig, serve: ServeConfig,
                 prompt_len: int, *, pos_offset: int = 0,
                 use_long_window: bool = False):
        self.params = params
        self.cfg = cfg
        self.serve = serve
        self.spec = SamplerSpec(
            prompt_len=prompt_len, gen_len=serve.gen_length,
            block_size=serve.block_size, conf_threshold=serve.conf_threshold,
            temperature=serve.temperature,
            cache_refresh_interval=serve.cache_refresh_interval,
            pos_offset=pos_offset)
        sampler = SAMPLERS[serve.sampler]
        kwargs = {}
        if serve.sampler == "cdlm" and use_long_window:
            kwargs["use_long_window"] = True

        def run(params, prompts, key, extras):
            return sampler(params, prompts, cfg=cfg, spec=self.spec, key=key,
                           extras=extras, **kwargs)

        self._run = jax.jit(run)
        self._warm = False

    def warmup(self, extras=None):
        b = self.serve.max_batch
        prompts = jnp.zeros((b, self.spec.prompt_len), jnp.int32)
        self._run(self.params, prompts, jax.random.PRNGKey(0),
                  extras or {}).tokens.block_until_ready()
        self._warm = True

    def generate(self, requests: Sequence[Request],
                 key=None) -> List[Response]:
        key = key if key is not None else jax.random.PRNGKey(0)
        out: List[Response] = []
        B = self.serve.max_batch
        for i in range(0, len(requests), B):
            chunk = list(requests[i:i + B])
            pad = B - len(chunk)
            prompts = np.stack([r.prompt for r in chunk] +
                               [chunk[-1].prompt] * pad)
            extras = {}
            if chunk[0].extras:
                for k in chunk[0].extras:
                    arrs = [r.extras[k] for r in chunk] + [chunk[-1].extras[k]] * pad
                    extras[k] = jnp.asarray(np.stack(arrs))
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            res = self._run(self.params, jnp.asarray(prompts), sub, extras)
            res.tokens.block_until_ready()
            dt = (time.perf_counter() - t0) / len(chunk)
            toks = np.asarray(res.tokens)
            steps = np.asarray(res.steps)
            glens = np.asarray(res.gen_lengths)
            for j, r in enumerate(chunk):
                out.append(Response(
                    id=r.id, tokens=toks[j, self.spec.prompt_len:],
                    gen_length=int(glens[j]), steps=int(steps[j]),
                    latency_s=dt))
        return out


def efficiency_report(responses: Sequence[Response]) -> Dict[str, float]:
    """Per-sample averages, the paper's reporting convention (App. A.3)."""
    lat = float(np.mean([r.latency_s for r in responses]))
    steps = float(np.mean([r.steps for r in responses]))
    glen = float(np.mean([r.gen_length for r in responses]))
    tps = glen / lat if lat > 0 else float("inf")
    return {"latency_s": lat, "steps": steps, "gen_length": glen, "tps": tps}
