"""Stdlib-only HTTP frontend over the incremental serving engines.

Endpoints (OpenAI-completions-shaped, token ids in place of text — this
repo has no tokenizer):

- ``POST /v1/completions`` — body::

      {"prompt": [int, ...],          # exactly engine prompt_len ids
       "max_tokens": int | null,
       "temperature": float | null,   # null -> ServeConfig default
       "conf_threshold": float | null,
       "seed": int | null,
       "eos_token_id": int | null,
       "stream": bool}

  Non-streaming responses carry the generated span (trimmed to
  ``gen_length``) in ``choices[0].token_ids``. With ``"stream": true``
  the response is Server-Sent Events: one ``data: {...}`` chunk per
  finalized *block* — CDLM's block-causal finalization commits a block
  exactly once, so each SSE chunk is final the moment it is sent — and a
  terminating ``data: [DONE]``. Streamed chunks concatenate to the exact
  non-streamed ``token_ids``.

- ``GET /healthz`` — liveness (``{"status": "ok"}``).

- ``GET /metrics`` — Prometheus text exposition surfacing the engine's
  ``page_pool_stats()`` / ``concurrency_stats()`` plus request counters.

A single scheduler thread owns the engine (the engines are not
thread-safe): HTTP handlers enqueue requests through
``engine.add_request`` under a lock and block on a per-request event
queue; the scheduler drains ``engine.step()`` and routes each
:class:`BlockEvent` to its request's queue. Mid-stream client
disconnects abort the request (``engine.abort``), freeing its lane/pages
without perturbing other lanes (non-streamed disconnects are only
detectable at response-write time, after the decode finished). If
``step()`` ever raises, the driver fails every pending request, stops,
and ``/healthz`` turns 500 with the error — requests never hang on a
silently dead scheduler.
"""
from __future__ import annotations

import json
import queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict

import numpy as np

from repro.serving.api import GenerationRequest, SamplingParams


class EngineDriver:
    """Single-threaded owner of an engine, fed by HTTP handler threads.

    ``submit``/``abort`` serialize with ``step()`` under ``cond`` (the
    engines are not thread-safe); a submission arriving mid-step therefore
    waits for the step to finish — which costs it nothing, since a request
    can only be admitted at the next block boundary anyway. ``metrics()``
    and ``/healthz`` read lock-free snapshots so observability stays
    responsive during long decode steps."""

    def __init__(self, engine):
        self.engine = engine
        self.cond = threading.Condition()
        self._queues: Dict[int, "queue.Queue"] = {}
        self._stop = False
        self.last_error: str = ""
        self.requests_total = 0
        self.completed_total = 0
        self.aborted_total = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="engine-driver")
        self._thread.start()

    @property
    def healthy(self) -> bool:
        return not self._stop and self._thread.is_alive()

    def submit(self, prompt, params: SamplingParams):
        """Enqueue one request; returns ``(request_id, event_queue)``. The
        queue yields :class:`BlockEvent` items and a final ``None``."""
        with self.cond:
            if self._stop:
                raise RuntimeError(
                    f"engine driver stopped: {self.last_error or 'shutdown'}")
            rid = self.engine.add_request(
                GenerationRequest(prompt=prompt, params=params))
            q: "queue.Queue" = queue.Queue()
            self._queues[rid] = q
            self.requests_total += 1
            self.cond.notify()
        return rid, q

    def abort(self, request_id: int) -> bool:
        with self.cond:
            found = self.engine.abort(request_id)
            # only detach the event queue when the engine really dropped
            # the request; a static-scheduler request already inside the
            # running chunk will still finish and must reach
            # completed_total (nobody reads its events — that's fine)
            q = self._queues.pop(request_id, None) if found else None
            if found:
                self.aborted_total += 1
        if q is not None:
            q.put(None)
        return found

    def metrics(self) -> str:
        # lock-free snapshot: counters are GIL-atomic int reads and the
        # stats methods only read host-side lists, so /metrics stays
        # responsive while a decode step holds the scheduler lock
        eng = self.engine
        lines = [
            "# TYPE cdlm_requests_total counter",
            f"cdlm_requests_total {self.requests_total}",
            "# TYPE cdlm_requests_completed_total counter",
            f"cdlm_requests_completed_total {self.completed_total}",
            "# TYPE cdlm_requests_aborted_total counter",
            f"cdlm_requests_aborted_total {self.aborted_total}",
            "# TYPE cdlm_requests_active gauge",
            f"cdlm_requests_active {len(self._queues)}",
        ]
        for src, prefix in ((getattr(eng, "page_pool_stats", None),
                             "cdlm_page_pool"),
                            (getattr(eng, "concurrency_stats", None),
                             "cdlm_lanes")):
            if src is None:
                continue
            for k, v in src().items():
                lines.append(f"# TYPE {prefix}_{k} gauge")
                lines.append(f"{prefix}_{k} {v}")
        return "\n".join(lines) + "\n"

    def shutdown(self):
        with self.cond:
            self._stop = True
            self.cond.notify()
        self._thread.join(timeout=5)

    def _loop(self):
        while True:
            with self.cond:
                while not self._stop and not self.engine.has_unfinished():
                    self.cond.wait(timeout=0.5)
                if self._stop:
                    return
                try:
                    events = self.engine.step()
                except Exception as e:  # noqa: BLE001 — fail pending
                    # requests loudly instead of hanging them on a dead
                    # scheduler thread; /healthz turns 500
                    self.last_error = f"{type(e).__name__}: {e}"
                    self._stop = True
                    dead = list(self._queues.values())
                    self._queues.clear()
                    for q in dead:
                        q.put(None)
                    return
                routes = []
                for ev in events:
                    q = self._queues.get(ev.request_id)
                    if q is None:
                        continue  # aborted between steps
                    routes.append((q, ev))
                    if ev.finished:
                        self._queues.pop(ev.request_id, None)
                        self.completed_total += 1
            for q, ev in routes:
                q.put(ev)
                if ev.finished:
                    q.put(None)


def _params_from_body(body: dict) -> SamplingParams:
    def opt(key, cast):
        v = body.get(key)
        return None if v is None else cast(v)

    return SamplingParams(
        temperature=opt("temperature", float),
        conf_threshold=opt("conf_threshold", float),
        max_tokens=opt("max_tokens", int),
        seed=opt("seed", int),
        eos_token_id=opt("eos_token_id", int))


class CompletionsHandler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    # quiet the default per-request stderr logging
    def log_message(self, fmt, *args):
        pass

    @property
    def driver(self) -> EngineDriver:
        return self.server.driver

    def _json(self, code: int, payload: dict):
        data = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self):
        if self.path == "/healthz":
            if self.driver.healthy:
                self._json(200, {"status": "ok"})
            else:
                self._json(500, {"status": "error",
                                 "error": self.driver.last_error})
        elif self.path == "/metrics":
            data = self.driver.metrics().encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(data)))
            self.end_headers()
            self.wfile.write(data)
        else:
            self._json(404, {"error": f"unknown path {self.path}"})

    def do_POST(self):
        if self.path != "/v1/completions":
            self._json(404, {"error": f"unknown path {self.path}"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(length) or b"{}")
            prompt = np.asarray(body["prompt"], np.int32)
            if prompt.ndim != 1:
                raise ValueError("prompt must be a flat list of token ids")
            params = _params_from_body(body)
        except (KeyError, ValueError, TypeError, json.JSONDecodeError) as e:
            self._json(400, {"error": str(e)})
            return
        try:
            rid, events = self.driver.submit(prompt, params)
        except ValueError as e:  # e.g. wrong prompt length
            self._json(400, {"error": str(e)})
            return
        except RuntimeError as e:  # driver stopped after a step() failure
            self._json(503, {"error": str(e)})
            return
        if body.get("stream"):
            self._stream_response(rid, events)
        else:
            self._full_response(rid, events)

    # -- response bodies ----------------------------------------------------
    def _drain(self, events):
        """Wait out this request's block events; returns its final output
        (None if the request was aborted server-side)."""
        output = None
        while True:
            ev = events.get()
            if ev is None:
                return output
            if ev.finished:
                output = ev.output

    def _choice(self, output, token_ids):
        return {"index": 0, "token_ids": token_ids,
                "finish_reason": output.finish_reason if output else None}

    def _full_response(self, rid, events):
        output = self._drain(events)
        try:
            if output is None:  # aborted / driver failed server-side
                self._json(503, {"error": "request aborted"})
                return
            ids = np.asarray(output.tokens)[:output.gen_length].tolist()
            self._json(200, {
                "id": f"cmpl-{rid}",
                "object": "text_completion",
                "choices": [self._choice(output, ids)],
                "usage": {"prompt_tokens": self.server.prompt_len,
                          "completion_tokens": output.gen_length,
                          "steps": output.steps},
            })
        except (BrokenPipeError, ConnectionResetError):
            pass  # client left; the decode already completed

    def _stream_response(self, rid, events):
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        sent = 0
        completed = False
        try:
            while True:
                ev = events.get()
                if ev is None:
                    break
                if ev.finished:
                    completed = True
                if ev.finished and ev.output is not None:
                    # trim the final block to gen_length; earlier blocks
                    # are always fully inside the generated span
                    take = max(0, ev.output.gen_length - sent)
                else:
                    take = len(ev.tokens)
                chunk = np.asarray(ev.tokens)[:take].tolist()
                sent += len(chunk)
                payload = {
                    "id": f"cmpl-{rid}",
                    "object": "text_completion.chunk",
                    "choices": [{
                        "index": 0, "token_ids": chunk, "block": ev.index,
                        "finish_reason": (ev.output.finish_reason
                                          if ev.finished and ev.output
                                          else None)}],
                }
                self.wfile.write(
                    f"data: {json.dumps(payload)}\n\n".encode())
                self.wfile.flush()
            if completed:
                self.wfile.write(b"data: [DONE]\n\n")
            else:
                # aborted server-side / driver died: make the truncation
                # visible instead of ending the stream like a success
                self.wfile.write(
                    b'data: {"error": "request aborted"}\n\n')
            self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            # client went away mid-stream: free the lane/pages
            self.driver.abort(rid)


class CompletionsServer(ThreadingHTTPServer):
    daemon_threads = True

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 8000):
        self.driver = EngineDriver(engine)
        self.prompt_len = engine.spec.prompt_len
        super().__init__((host, port), CompletionsHandler)

    def shutdown(self):
        super().shutdown()
        self.driver.shutdown()


def serve_http(engine, host: str = "127.0.0.1", port: int = 8000,
               *, block: bool = True) -> CompletionsServer:
    """Boot the HTTP frontend over ``engine``. ``port=0`` binds an
    ephemeral port (read it back from ``server.server_address``). With
    ``block=False`` the server runs on a daemon thread and is returned
    immediately (tests / smoke drivers)."""
    server = CompletionsServer(engine, host, port)
    if block:
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.shutdown()
        return server
    threading.Thread(target=server.serve_forever, daemon=True,
                     name="http-server").start()
    return server
