"""Request-level serving API types.

The serving layer is built around four small types:

- :class:`SamplingParams` — per-request sampling knobs. Every field
  defaults to ``None`` = "inherit the engine's :class:`ServeConfig`", so a
  batch of bare requests decodes exactly as before the request-level API
  existed. (One caveat on the *static* engine with a sampled default,
  ``ServeConfig.temperature > 0``: a chunk is a single jit call, so one
  request with explicit params moves its whole chunk to per-request RNG
  streams — bare chunk-mates then draw from ``PRNGKey(id)`` instead of
  the historical shared batch stream.)
  One continuous batch can mix requests with different temperatures,
  confidence thresholds, stop tokens and seeds; per-lane RNG streams keep
  every lane bit-identical to its isolated decode
  (see :class:`repro.core.block_loop.LaneParams`).

- :class:`GenerationRequest` — one unit of work: a prompt plus its
  params. ``id=None`` lets the engine auto-assign a unique monotonically
  increasing id (explicit ids must be unique within a call/engine).
  Exported as ``Request`` for backward compatibility; the legacy
  ``max_tokens`` field is honored when ``params.max_tokens`` is unset.

- :class:`BlockEvent` — the streaming unit. CDLM's block-causal
  finalization makes exact block-at-a-time streaming natural: a committed
  block never changes, so the engine emits it the moment it finalizes.
  Concatenating a request's block events reproduces the generated span of
  its :class:`GenerationOutput` token-for-token (trim to ``gen_length``).

- :class:`GenerationOutput` — the final per-request result (exported as
  ``Response`` for backward compatibility). ``finish_reason`` follows the
  OpenAI convention: ``"stop"`` when the (per-request) EOS token appeared,
  ``"length"`` when the generation budget ran out.

Request lifecycle against the incremental engine core::

    rid = engine.add_request(GenerationRequest(prompt, params=sp))
    while engine.has_unfinished():
        for ev in engine.step():          # blocks finalized this boundary
            consume(ev)                   # ev.output set when ev.finished
    # or: engine.abort(rid) at any block boundary

``engine.generate(requests)`` and ``engine.stream(requests)`` are thin
wrappers that drain the stepper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ServeConfig


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling parameters; ``None`` inherits ``ServeConfig``.

    temperature: 0 = greedy argmax; > 0 = categorical over
        ``softmax(logits / T)`` with a per-request RNG stream.
    conf_threshold: τ of the threshold finalize rule (CDLM §4.3).
    max_tokens: generation cap; the continuous engine rounds it up to a
        whole number of blocks, the static engine trims the returned span.
    seed: RNG seed for sampled decoding. Unset → derived from the request
        id, so re-serving the same id reproduces the same stream.
    eos_token_id: per-request stop-token override.
    """
    temperature: Optional[float] = None
    conf_threshold: Optional[float] = None
    max_tokens: Optional[int] = None
    seed: Optional[int] = None
    eos_token_id: Optional[int] = None

    @property
    def is_engine_default(self) -> bool:
        """True when no field that alters the decode loop is set —
        ``max_tokens`` alone keeps a request on the engine's scalar fast
        path (it only caps/trims, it never changes selection)."""
        return (self.temperature is None and self.conf_threshold is None
                and self.seed is None and self.eos_token_id is None)

    def resolve(self, serve: ServeConfig, cfg: ModelConfig, *,
                request_id: int,
                legacy_max_tokens: Optional[int] = None
                ) -> "ResolvedSamplingParams":
        """Fill unset fields from the engine config (and the request id
        for the default seed)."""
        max_tokens = (self.max_tokens if self.max_tokens is not None
                      else legacy_max_tokens)
        return ResolvedSamplingParams(
            temperature=(self.temperature if self.temperature is not None
                         else serve.temperature),
            conf_threshold=(self.conf_threshold
                            if self.conf_threshold is not None
                            else serve.conf_threshold),
            max_tokens=max_tokens,
            seed=self.seed if self.seed is not None else request_id,
            eos_token_id=(self.eos_token_id
                          if self.eos_token_id is not None
                          else cfg.eos_token_id))


@dataclasses.dataclass(frozen=True)
class ResolvedSamplingParams:
    """:class:`SamplingParams` with every field made concrete."""
    temperature: float
    conf_threshold: float
    max_tokens: Optional[int]
    seed: int
    eos_token_id: int


@dataclasses.dataclass
class GenerationRequest:
    """One serving request. Field order matches the legacy ``Request``
    (all call sites use keywords; ``params`` is the new trailing field)."""
    prompt: np.ndarray                       # (P,) int32
    extras: Optional[Dict[str, np.ndarray]] = None
    id: Optional[int] = None                 # None -> engine-assigned
    max_tokens: Optional[int] = None         # legacy; params.max_tokens wins
    arrival_s: float = 0.0                   # arrival offset in the trace
    params: Optional[SamplingParams] = None


#: Backward-compatible name; the engines accept either spelling.
Request = GenerationRequest


@dataclasses.dataclass
class GenerationOutput:
    """Final result of one request (legacy name: ``Response``)."""
    id: int
    tokens: np.ndarray                       # generated span (gen_len,)
    gen_length: int
    steps: int
    # static Engine: per-sample share of batch compute time (arrival_s is
    # not modeled); ContinuousEngine: true arrival -> completion, queueing
    # included. Compare throughput across engines via wall-clock, not this.
    latency_s: float
    queue_s: float = 0.0                     # arrival -> admission (continuous)
    finish_reason: str = "length"            # "stop" (EOS) | "length"


Response = GenerationOutput


@dataclasses.dataclass
class BlockEvent:
    """One finalized block, emitted by ``engine.step()`` the moment the
    block commits (block-causal finalization: it will never change)."""
    request_id: int
    index: int                               # block index in the gen span
    start: int                               # token offset = index * B
    tokens: np.ndarray                       # (block_size,) block tokens
    finished: bool = False                   # last block of the request
    output: Optional[GenerationOutput] = None  # set when finished


def normalize_requests(requests, next_id: int, *, taken=frozenset()):
    """Engine-assigned unique request ids: auto-assign monotonically from
    ``next_id`` when ``req.id`` is None, reject duplicates (within the call
    and against ``taken``, the ids already in flight). Explicit ids advance
    the counter past themselves, so auto ids never collide with any id the
    engine has already seen — completed ones included. Returns the next
    unused id. Mutates ``req.id`` in place."""
    seen = set(taken)
    for req in requests:
        if req.id is None:
            while next_id in seen:
                next_id += 1
            req.id = next_id
            next_id += 1
        elif req.id in seen:
            raise ValueError(
                f"duplicate request id {req.id}: ids must be unique within "
                "a call (leave id=None for engine-assigned unique ids)")
        else:
            next_id = max(next_id, req.id + 1)
        seen.add(req.id)
    return next_id
