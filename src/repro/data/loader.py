"""Deterministic host-side data pipeline: pre-generates a corpus of
(prompt, answer) pairs and serves epochs of shuffled batches — the
offline-dataset structure of paper App. A.1 at toy scale."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.data.synthetic import TaskSpec, sample_batch


class Corpus:
    def __init__(self, spec: TaskSpec, n_examples: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        data = sample_batch(rng, spec, n_examples)
        self.spec = spec
        self.prompt = data["prompt"]
        self.answer = data["answer"]
        self.n = n_examples

    def batches(self, batch_size: int, *, seed: int = 0,
                epochs: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        for _ in range(epochs):
            order = rng.permutation(self.n)
            for i in range(0, self.n - batch_size + 1, batch_size):
                idx = order[i:i + batch_size]
                yield {"prompt": self.prompt[idx], "answer": self.answer[idx]}

    def eval_batch(self, n: int) -> Dict[str, np.ndarray]:
        return {"prompt": self.prompt[:n], "answer": self.answer[:n]}
