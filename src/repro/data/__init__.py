from repro.data.loader import Corpus  # noqa: F401
from repro.data.synthetic import TaskSpec, answer_mask, sample_batch, score, verify  # noqa: F401
