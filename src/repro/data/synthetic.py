"""Synthetic reasoning corpora with exact verifiers.

The paper trains on math-reasoning corpora (Bespoke-Stratos / DParallel) and
scores with exact-match / pass@1. Offline, we substitute two synthetic task
families whose answers are mechanically verifiable, giving the same metric
structure (Score / TPS / Latency / Steps / Gen-length as Tables 1–2):

- ``sort``:  prompt = <SORT> x_1..x_k <ASK>, answer = sorted(x) <EOS>.
  Requires global aggregation over the prompt — benefits from bidirectional
  context, a DLM-friendly task.
- ``add``:   prompt = <ADD> digits(a) <PLUS> digits(b) <ASK>,
  answer = digits(a+b) <EOS>. Multi-digit carry propagation — a chain-of-
  dependency task where naive parallel finalization degrades, mirroring the
  paper's Table 4 step-truncation collapse.

Token space: 0..9 digits mapped to ids 10..19; value tokens for sort are
ids 10..(10+range); specials below 10.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

PAD, EOS, ASK, PLUS, SORT_TAG, ADD_TAG = 0, 1, 2, 3, 4, 5
SPECIALS = 10  # ids < 10 reserved
DIGIT0 = 10    # digit d -> DIGIT0 + d


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str                  # sort | add
    vocab_size: int            # must match ModelConfig.vocab_size
    prompt_len: int = 16
    gen_len: int = 16
    sort_k: int = 8            # numbers to sort
    sort_range: int = 64       # values in [0, sort_range)
    add_digits: int = 5        # digits per operand

    def __post_init__(self):
        if self.name == "sort":
            assert SPECIALS + self.sort_range < self.vocab_size - 1
            assert self.sort_k + 2 <= self.prompt_len
            assert self.sort_k + 1 <= self.gen_len
        else:
            assert 2 * self.add_digits + 3 <= self.prompt_len
            assert self.add_digits + 2 <= self.gen_len


def _pad(arr, length):
    out = np.full((len(arr), length), PAD, np.int32)
    for i, row in enumerate(arr):
        out[i, :len(row)] = row
    return out


def sample_batch(rng: np.random.Generator, spec: TaskSpec,
                 batch: int) -> Dict[str, np.ndarray]:
    """Returns {"prompt": (b, P), "answer": (b, G)} (answer EOS-terminated,
    PAD-padded)."""
    prompts, answers = [], []
    if spec.name == "sort":
        for _ in range(batch):
            xs = rng.integers(0, spec.sort_range, spec.sort_k)
            prompts.append([SORT_TAG] + [DIGIT0 + int(v) for v in xs] + [ASK])
            answers.append([DIGIT0 + int(v) for v in sorted(xs)] + [EOS])
    elif spec.name == "add":
        hi = 10 ** spec.add_digits
        for _ in range(batch):
            a, b = int(rng.integers(0, hi)), int(rng.integers(0, hi))
            da = [DIGIT0 + int(c) for c in str(a)]
            db = [DIGIT0 + int(c) for c in str(b)]
            prompts.append([ADD_TAG] + da + [PLUS] + db + [ASK])
            answers.append([DIGIT0 + int(c) for c in str(a + b)] + [EOS])
    else:
        raise ValueError(spec.name)
    return {"prompt": _pad(prompts, spec.prompt_len),
            "answer": _pad(answers, spec.gen_len)}


def verify(prompt_row: np.ndarray, gen_row: np.ndarray, spec: TaskSpec) -> bool:
    """Exact-match scorer (the Tables 1–2 'Score' column at toy scale)."""
    gen = list(gen_row)
    ans = gen[:gen.index(EOS)] if EOS in gen else gen
    p = list(prompt_row)
    try:
        if spec.name == "sort":
            body = p[p.index(SORT_TAG) + 1: p.index(ASK)]
            want = sorted(body)
        else:
            plus, ask = p.index(PLUS), p.index(ASK)
            a = int("".join(str(t - DIGIT0) for t in p[p.index(ADD_TAG) + 1: plus]))
            b = int("".join(str(t - DIGIT0) for t in p[plus + 1: ask]))
            want = [DIGIT0 + int(c) for c in str(a + b)]
    except (ValueError, IndexError):
        return False
    return ans == want


def score(prompts: np.ndarray, tokens: np.ndarray, prompt_len: int,
          spec: TaskSpec) -> float:
    gens = tokens[:, prompt_len:]
    ok = [verify(p, g, spec) for p, g in zip(np.asarray(prompts), np.asarray(gens))]
    return float(np.mean(ok))


def answer_mask(answers: np.ndarray) -> np.ndarray:
    """Maskable positions for the DLM loss: everything up to and including
    EOS (PAD tail excluded)."""
    b, g = answers.shape
    is_eos = answers == EOS
    has = is_eos.any(axis=1)
    first = np.where(has, is_eos.argmax(axis=1), g - 1)
    idx = np.arange(g)[None, :]
    return idx <= first[:, None]
