"""Exact block-wise caches (KV + SSM/RWKV state) — paper §4.3.

The cache mirrors the transformer's per-slot emission structure: a tuple
over period slots of dicts whose leaves are stacked over periods:

- attention slots:  ``{"k": (np, b, max_len, n_kv, hd), "v": ...}``
- cross-attention (whisper): ``{"ck": (np, b, enc_len, n_kv, hd), "cv": ...}``
- mamba slots:      ``{"conv": (np, b, d_conv-1, e), "ssm": (np, b, e, N)}``
- rwkv slots:       ``{"S": (np, b, H, hs, hs), "tm_shift": (np, b, d),
                       "cm_shift": (np, b, d)}``

``commit`` writes a block's emissions at ``offset`` (KV) / replaces state
(SSM) — called only at block completion, so caching stays *exact*: committed
KV always derives from finalized token values (the "commit pass").

``reset`` / ``commit_rows`` are the per-lane variants: they touch only the
selected batch lanes (each at its own offset), so a serving scheduler can
evict a finished sequence and admit a new one mid-flight without perturbing
its neighbors — safe precisely because block-causal caching is exact.

Two memory layouts, switched by ``CACHE_LAYOUTS``:

- **dense** (:func:`init_cache`): every lane preallocates ``max_len`` KV
  rows, so batch capacity is bound by the longest possible request.
- **paged** (:func:`init_paged_cache`): KV lives in a global page pool of
  ``(n_pages, page_size, n_kv, hd)`` pages shared by all lanes, plus a
  per-lane page table mapping sequence-block index -> page. Page ``p`` of a
  lane holds absolute positions ``[p*page_size, (p+1)*page_size)``; entries
  are ``FREE`` (-1) until :func:`alloc` assigns a pool page. Lanes only
  consume pages for positions they actually commit, so a pool of the same
  byte budget sustains more concurrent lanes at mixed generation lengths.
  SSM/RWKV state slots stay dense — they are O(1) per lane.

``reset`` and ``commit_rows`` are polymorphic over both layouts, so the
block-decode loop and the serving engines are layout-agnostic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, MAMBA, RWKV, RWKV_CM, ModelConfig
from repro.models import rwkv6 as R


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> tuple:
    """Allocate empty cache buffers for every period slot."""
    dt = jnp.dtype(dtype or cfg.dtype)
    np_ = cfg.n_periods
    slots = []
    for mixer, ffn in cfg.layer_period:
        slot: dict = {}
        if mixer in (ATTN, ATTN_LOCAL):
            kv_shape = (np_, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            slot["k"] = jnp.zeros(kv_shape, dt)
            slot["v"] = jnp.zeros(kv_shape, dt)
            if cfg.is_encoder_decoder:
                cshape = (np_, batch, cfg.encoder_seq_len, cfg.n_kv_heads, cfg.head_dim)
                slot["ck"] = jnp.zeros(cshape, dt)
                slot["cv"] = jnp.zeros(cshape, dt)
        elif mixer == MAMBA:
            e = cfg.mamba_expand * cfg.d_model
            slot["conv"] = jnp.zeros((np_, batch, cfg.mamba_d_conv - 1, e), dt)
            slot["ssm"] = jnp.zeros((np_, batch, e, cfg.mamba_d_state), jnp.float32)
        elif mixer == RWKV:
            H, hs = R.n_rwkv_heads(cfg), cfg.rwkv_head_size
            slot["S"] = jnp.zeros((np_, batch, H, hs, hs), jnp.float32)
            slot["tm_shift"] = jnp.zeros((np_, batch, cfg.d_model), dt)
        if ffn == RWKV_CM:
            slot["cm_shift"] = jnp.zeros((np_, batch, cfg.d_model), dt)
        slots.append(slot)
    return tuple(slots)


def commit(cache: tuple, emissions: tuple, offset) -> tuple:
    """Write a block's emissions into the cache.

    KV emissions ``(np, b, L_blk, kv, hd)`` are inserted at sequence position
    ``offset``; state emissions (ssm/rwkv/conv/shift/cross) replace the old
    state wholesale.
    """
    new_slots = []
    for cslot, eslot in zip(cache, emissions):
        ns = dict(cslot)
        for key, val in eslot.items():
            if key in ("k", "v"):
                buf = cslot[key]
                ns[key] = jax.lax.dynamic_update_slice(
                    buf, val.astype(buf.dtype), (0, 0, offset, 0, 0))
            elif key in cslot:
                ns[key] = val.astype(cslot[key].dtype)
        new_slots.append(ns)
    return tuple(new_slots)


def _row_mask(rows, batch: int) -> jnp.ndarray:
    """Normalize ``rows`` (bool lane mask or int lane indices) to (b,) bool."""
    rows = jnp.asarray(rows)
    if rows.dtype == jnp.bool_:
        return rows
    return jnp.zeros((batch,), bool).at[rows].set(True)


def _broadcast_rows(mask, leaf):
    """Reshape a (b,) lane mask to broadcast against a (np, b, ...) leaf."""
    return mask.reshape((1, -1) + (1,) * (leaf.ndim - 2))


def reset(cache, rows):
    """Zero the selected batch lanes of every cache buffer.

    ``rows``: (b,) bool lane mask (or int lane indices). Neighboring lanes
    are untouched — the primitive that lets a serving scheduler recycle one
    finished lane while the rest of the batch keeps decoding.

    Polymorphic: a :class:`PagedCache` releases the lanes' pages back to the
    pool (stale page contents are never readable — every position below a
    lane's ``cache_len`` is re-committed before it becomes visible) and
    zeroes the dense per-lane state leaves.
    """
    if isinstance(cache, PagedCache):
        return free(cache, rows)
    batch = jax.tree_util.tree_leaves(cache)[0].shape[1]
    mask = _row_mask(rows, batch)
    return jax.tree_util.tree_map(
        lambda leaf: jnp.where(_broadcast_rows(mask, leaf),
                               jnp.zeros((), leaf.dtype), leaf), cache)


def commit_rows(cache, emissions: tuple, offsets, rows):
    """Per-lane :func:`commit`: write emissions only for the selected lanes,
    each at its own sequence ``offset``.

    ``offsets``: scalar or (b,) int — KV insert position per lane;
    ``rows``: (b,) bool lane mask (or int lane indices). Lanes outside
    ``rows`` keep their old cache contents bit-for-bit.

    Polymorphic: a :class:`PagedCache` scatters KV through each lane's page
    table instead of into per-lane dense rows.
    """
    if isinstance(cache, PagedCache):
        return _commit_rows_paged(cache, emissions, offsets, rows)
    batch = jax.tree_util.tree_leaves(cache)[0].shape[1]
    mask = _row_mask(rows, batch)
    offsets = jnp.broadcast_to(jnp.asarray(offsets, jnp.int32), (batch,))

    def write_kv(buf, val):
        upd = jax.vmap(
            lambda b_l, v_l, off: jax.lax.dynamic_update_slice(
                b_l, v_l.astype(b_l.dtype), (0, off, 0, 0)),
            in_axes=(1, 1, 0), out_axes=1)(buf, val, offsets)
        return jnp.where(_broadcast_rows(mask, buf), upd, buf)

    new_slots = []
    for cslot, eslot in zip(cache, emissions):
        ns = dict(cslot)
        for key, val in eslot.items():
            if key in ("k", "v"):
                ns[key] = write_kv(cslot[key], val)
            elif key in cslot:
                old = cslot[key]
                ns[key] = jnp.where(_broadcast_rows(mask, old),
                                    val.astype(old.dtype), old)
        new_slots.append(ns)
    return tuple(new_slots)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(cache))


# ---------------------------------------------------------------------------
# Paged layout
# ---------------------------------------------------------------------------
DENSE = "dense"
PAGED = "paged"
CACHE_LAYOUTS = (DENSE, PAGED)

FREE = -1  # sentinel for unallocated page-table entries / unowned pool pages


class PagedCache(NamedTuple):
    """Block-paged KV cache: a global page pool plus per-lane page tables.

    ``slots`` mirrors the dense cache structure, except attention K/V leaves
    are pools ``(np, n_pages, page_size, n_kv, hd)`` shared across lanes;
    SSM/RWKV/shift leaves stay dense ``(np, b, ...)``.

    ``page_table`` (b, n_tables) int32 maps a lane's sequence-block index to
    a pool page (``FREE`` = unallocated); ``page_owner`` (n_pages,) int32
    records which lane holds each pool page (``FREE`` = available) — the
    allocator's free list and the occupancy report derive from it.
    """
    slots: tuple
    page_table: jnp.ndarray
    page_owner: jnp.ndarray

    @property
    def page_size(self) -> int:
        for slot in self.slots:
            if "k" in slot:
                return slot["k"].shape[2]
        raise ValueError("paged cache has no attention slots")

    @property
    def n_pages(self) -> int:
        return self.page_owner.shape[0]

    @property
    def n_lanes(self) -> int:
        return self.page_table.shape[0]


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     n_pages: int, page_size: int, dtype=None) -> PagedCache:
    """Allocate a page pool sized independently of ``batch * max_len``.

    ``max_len`` only bounds the per-lane page *table* width (tiny int32
    rows); KV bytes scale with ``n_pages * page_size``, not with the longest
    possible request.
    """
    if cfg.is_attention_free:
        raise ValueError("paged layout needs attention KV; "
                         f"{cfg.name} carries only O(1) recurrent state")
    if cfg.is_encoder_decoder:
        raise ValueError("paged layout does not support encoder-decoder "
                         "cross-attention caches yet")
    dt = jnp.dtype(dtype or cfg.dtype)
    np_ = cfg.n_periods
    n_tables = -(-max_len // page_size)
    slots = []
    for mixer, ffn in cfg.layer_period:
        slot: dict = {}
        if mixer in (ATTN, ATTN_LOCAL):
            pool_shape = (np_, n_pages, page_size, cfg.n_kv_heads,
                          cfg.head_dim)
            slot["k"] = jnp.zeros(pool_shape, dt)
            slot["v"] = jnp.zeros(pool_shape, dt)
        elif mixer == MAMBA:
            e = cfg.mamba_expand * cfg.d_model
            slot["conv"] = jnp.zeros((np_, batch, cfg.mamba_d_conv - 1, e), dt)
            slot["ssm"] = jnp.zeros((np_, batch, e, cfg.mamba_d_state),
                                    jnp.float32)
        elif mixer == RWKV:
            H, hs = R.n_rwkv_heads(cfg), cfg.rwkv_head_size
            slot["S"] = jnp.zeros((np_, batch, H, hs, hs), jnp.float32)
            slot["tm_shift"] = jnp.zeros((np_, batch, cfg.d_model), dt)
        if ffn == RWKV_CM:
            slot["cm_shift"] = jnp.zeros((np_, batch, cfg.d_model), dt)
        slots.append(slot)
    return PagedCache(
        slots=tuple(slots),
        page_table=jnp.full((batch, n_tables), FREE, jnp.int32),
        page_owner=jnp.full((n_pages,), FREE, jnp.int32))


def pages_for_span(start: int, stop: int, page_size: int) -> int:
    """Number of page-table slots covering absolute positions [start, stop)."""
    if stop <= start:
        return 0
    return -(-stop // page_size) - start // page_size


def alloc(paged: PagedCache, rows, starts, stops):
    """Ensure pages covering ``[start, stop)`` are allocated per lane.

    ``rows``: (b,) bool lane mask (or int indices); ``starts``/``stops``:
    scalar or (b,) int32 absolute sequence positions. Pages are taken
    lowest-index-first, lanes served in index order (lane order is the
    scheduler's priority order, which keeps page-starved rounds
    deadlock-free: lane 0's request is always served first).

    Returns ``(paged, ok)`` where ``ok`` (b,) marks selected lanes whose
    span is now fully backed by pages; a lane that could not get every page
    it needed keeps its table row unchanged (all-or-nothing).
    """
    b = paged.n_lanes
    n_pages = paged.n_pages
    page = paged.page_size
    mask = _row_mask(rows, b)
    starts = jnp.broadcast_to(jnp.asarray(starts, jnp.int32), (b,))
    stops = jnp.broadcast_to(jnp.asarray(stops, jnp.int32), (b,))
    n_t = paged.page_table.shape[1]
    tids = jnp.arange(n_t, dtype=jnp.int32)

    def lane_step(owner, inp):
        row, sel, start, stop, lane = inp
        covers = (tids * page < stop) & ((tids + 1) * page > start)
        need = covers & (row == FREE) & sel
        free_mask = owner == FREE
        ok = sel & (jnp.sum(need) <= jnp.sum(free_mask))
        # stable list of free pages, lowest index first
        pidx = jnp.arange(n_pages, dtype=jnp.int32)
        freelist = jnp.argsort(jnp.where(free_mask, pidx, n_pages + pidx))
        rank = jnp.cumsum(need.astype(jnp.int32)) - 1
        cand = freelist[jnp.clip(rank, 0, n_pages - 1)].astype(jnp.int32)
        take = need & ok
        row = jnp.where(take, cand, row)
        # mark taken pages as owned (index n_pages = dropped no-op)
        scatter_idx = jnp.where(take, cand, n_pages)
        owner = owner.at[scatter_idx].set(lane, mode="drop")
        return owner, (row, ok)

    owner, (table, ok) = jax.lax.scan(
        lane_step, paged.page_owner,
        (paged.page_table, mask, starts, stops,
         jnp.arange(b, dtype=jnp.int32)))
    return paged._replace(page_table=table, page_owner=owner), ok


def free(paged: PagedCache, rows) -> PagedCache:
    """Release the selected lanes' pages back to the pool and zero their
    dense per-lane state leaves. Pool page *contents* are left as-is: a page
    is only readable below its new owner's ``cache_len``, and every such
    position is re-committed first, so reuse is residue-free.
    """
    b = paged.n_lanes
    mask = _row_mask(rows, b)
    owned_by_freed = mask[jnp.clip(paged.page_owner, 0, b - 1)] \
        & (paged.page_owner != FREE)
    owner = jnp.where(owned_by_freed, FREE, paged.page_owner)
    table = jnp.where(mask[:, None], FREE, paged.page_table)

    def clear(leaf):
        if leaf.ndim >= 2 and leaf.shape[1] == b:
            return jnp.where(_broadcast_rows(mask, leaf),
                             jnp.zeros((), leaf.dtype), leaf)
        return leaf

    slots = tuple(
        {k: (v if k in ("k", "v") else clear(v)) for k, v in slot.items()}
        for slot in paged.slots)
    return paged._replace(slots=slots, page_table=table, page_owner=owner)


def _commit_rows_paged(paged: PagedCache, emissions: tuple, offsets,
                       rows) -> PagedCache:
    """Paged :func:`commit_rows`: KV emissions are scattered through each
    lane's page table (pages must already be allocated via :func:`alloc`);
    dense state emissions replace the old state on the selected lanes."""
    b = paged.n_lanes
    page = paged.page_size
    n_pages = paged.n_pages
    mask = _row_mask(rows, b)
    offsets = jnp.broadcast_to(jnp.asarray(offsets, jnp.int32), (b,))

    def write_kv(pool, val):
        Lb = val.shape[2]
        pos = offsets[:, None] + jnp.arange(Lb, dtype=jnp.int32)[None, :]
        tbl_idx = jnp.clip(pos // page, 0, paged.page_table.shape[1] - 1)
        pid = jnp.take_along_axis(paged.page_table, tbl_idx, axis=1)
        sin = pos % page
        # route non-selected lanes (and unallocated pages) out of bounds so
        # the scatter drops them
        pid = jnp.where(mask[:, None] & (pid != FREE), pid, n_pages)
        # val (np, b, Lb, kv, hd) scatters into pool (np, n_pages, page, kv, hd)
        return pool.at[:, pid, sin].set(val.astype(pool.dtype), mode="drop")

    new_slots = []
    for cslot, eslot in zip(paged.slots, emissions):
        ns = dict(cslot)
        for key, val in eslot.items():
            if key in ("k", "v"):
                ns[key] = write_kv(cslot[key], val)
            elif key in cslot:
                old = cslot[key]
                ns[key] = jnp.where(_broadcast_rows(mask, old),
                                    val.astype(old.dtype), old)
        new_slots.append(ns)
    return paged._replace(slots=tuple(new_slots))


def gather_dense(paged: PagedCache) -> tuple:
    """Materialize the dense-layout view of a paged cache: K/V pools are
    gathered through the page tables into ``(np, b, n_tables*page, kv, hd)``
    buffers. Positions backed by unallocated pages hold arbitrary bytes —
    they are only compared/read below ``cache_len``. Test/debug helper; the
    decode path gathers lazily inside the attention slot instead."""
    table = jnp.clip(paged.page_table, 0, paged.n_pages - 1)
    b, n_t = paged.page_table.shape

    def view(pool):
        g = pool[:, table]                       # (np, b, n_t, page, kv, hd)
        return g.reshape(g.shape[0], b, n_t * paged.page_size,
                         *g.shape[4:])

    return tuple(
        {k: (view(v) if k in ("k", "v") else v) for k, v in slot.items()}
        for slot in paged.slots)


def free_page_count(paged: PagedCache) -> jnp.ndarray:
    return jnp.sum(paged.page_owner == FREE)
