"""Exact block-wise caches (KV + SSM/RWKV state) — paper §4.3.

The cache mirrors the transformer's per-slot emission structure: a tuple
over period slots of dicts whose leaves are stacked over periods:

- attention slots:  ``{"k": (np, b, max_len, n_kv, hd), "v": ...}``
- cross-attention (whisper): ``{"ck": (np, b, enc_len, n_kv, hd), "cv": ...}``
- mamba slots:      ``{"conv": (np, b, d_conv-1, e), "ssm": (np, b, e, N)}``
- rwkv slots:       ``{"S": (np, b, H, hs, hs), "tm_shift": (np, b, d),
                       "cm_shift": (np, b, d)}``

``commit`` writes a block's emissions at ``offset`` (KV) / replaces state
(SSM) — called only at block completion, so caching stays *exact*: committed
KV always derives from finalized token values (the "commit pass").
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, MAMBA, RWKV, RWKV_CM, ModelConfig
from repro.models import mamba as M
from repro.models import rwkv6 as R


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> tuple:
    """Allocate empty cache buffers for every period slot."""
    dt = jnp.dtype(dtype or cfg.dtype)
    np_ = cfg.n_periods
    slots = []
    for mixer, ffn in cfg.layer_period:
        slot: dict = {}
        if mixer in (ATTN, ATTN_LOCAL):
            kv_shape = (np_, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            slot["k"] = jnp.zeros(kv_shape, dt)
            slot["v"] = jnp.zeros(kv_shape, dt)
            if cfg.is_encoder_decoder:
                cshape = (np_, batch, cfg.encoder_seq_len, cfg.n_kv_heads, cfg.head_dim)
                slot["ck"] = jnp.zeros(cshape, dt)
                slot["cv"] = jnp.zeros(cshape, dt)
        elif mixer == MAMBA:
            e = cfg.mamba_expand * cfg.d_model
            slot["conv"] = jnp.zeros((np_, batch, cfg.mamba_d_conv - 1, e), dt)
            slot["ssm"] = jnp.zeros((np_, batch, e, cfg.mamba_d_state), jnp.float32)
        elif mixer == RWKV:
            H, hs = R.n_rwkv_heads(cfg), cfg.rwkv_head_size
            slot["S"] = jnp.zeros((np_, batch, H, hs, hs), jnp.float32)
            slot["tm_shift"] = jnp.zeros((np_, batch, cfg.d_model), dt)
        if ffn == RWKV_CM:
            slot["cm_shift"] = jnp.zeros((np_, batch, cfg.d_model), dt)
        slots.append(slot)
    return tuple(slots)


def commit(cache: tuple, emissions: tuple, offset) -> tuple:
    """Write a block's emissions into the cache.

    KV emissions ``(np, b, L_blk, kv, hd)`` are inserted at sequence position
    ``offset``; state emissions (ssm/rwkv/conv/shift/cross) replace the old
    state wholesale.
    """
    new_slots = []
    for cslot, eslot in zip(cache, emissions):
        ns = dict(cslot)
        for key, val in eslot.items():
            if key in ("k", "v"):
                buf = cslot[key]
                ns[key] = jax.lax.dynamic_update_slice(
                    buf, val.astype(buf.dtype), (0, 0, offset, 0, 0))
            elif key in cslot:
                ns[key] = val.astype(cslot[key].dtype)
        new_slots.append(ns)
    return tuple(new_slots)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(cache))
