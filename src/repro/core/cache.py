"""Exact block-wise caches (KV + SSM/RWKV state) — paper §4.3.

The cache mirrors the transformer's per-slot emission structure: a tuple
over period slots of dicts whose leaves are stacked over periods:

- attention slots:  ``{"k": (np, b, max_len, n_kv, hd), "v": ...}``
- cross-attention (whisper): ``{"ck": (np, b, enc_len, n_kv, hd), "cv": ...}``
- mamba slots:      ``{"conv": (np, b, d_conv-1, e), "ssm": (np, b, e, N)}``
- rwkv slots:       ``{"S": (np, b, H, hs, hs), "tm_shift": (np, b, d),
                       "cm_shift": (np, b, d)}``

``commit`` writes a block's emissions at ``offset`` (KV) / replaces state
(SSM) — called only at block completion, so caching stays *exact*: committed
KV always derives from finalized token values (the "commit pass").

``reset`` / ``commit_rows`` are the per-lane variants: they touch only the
selected batch lanes (each at its own offset), so a serving scheduler can
evict a finished sequence and admit a new one mid-flight without perturbing
its neighbors — safe precisely because block-causal caching is exact.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ATTN_LOCAL, MAMBA, RWKV, RWKV_CM, ModelConfig
from repro.models import mamba as M
from repro.models import rwkv6 as R


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> tuple:
    """Allocate empty cache buffers for every period slot."""
    dt = jnp.dtype(dtype or cfg.dtype)
    np_ = cfg.n_periods
    slots = []
    for mixer, ffn in cfg.layer_period:
        slot: dict = {}
        if mixer in (ATTN, ATTN_LOCAL):
            kv_shape = (np_, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            slot["k"] = jnp.zeros(kv_shape, dt)
            slot["v"] = jnp.zeros(kv_shape, dt)
            if cfg.is_encoder_decoder:
                cshape = (np_, batch, cfg.encoder_seq_len, cfg.n_kv_heads, cfg.head_dim)
                slot["ck"] = jnp.zeros(cshape, dt)
                slot["cv"] = jnp.zeros(cshape, dt)
        elif mixer == MAMBA:
            e = cfg.mamba_expand * cfg.d_model
            slot["conv"] = jnp.zeros((np_, batch, cfg.mamba_d_conv - 1, e), dt)
            slot["ssm"] = jnp.zeros((np_, batch, e, cfg.mamba_d_state), jnp.float32)
        elif mixer == RWKV:
            H, hs = R.n_rwkv_heads(cfg), cfg.rwkv_head_size
            slot["S"] = jnp.zeros((np_, batch, H, hs, hs), jnp.float32)
            slot["tm_shift"] = jnp.zeros((np_, batch, cfg.d_model), dt)
        if ffn == RWKV_CM:
            slot["cm_shift"] = jnp.zeros((np_, batch, cfg.d_model), dt)
        slots.append(slot)
    return tuple(slots)


def commit(cache: tuple, emissions: tuple, offset) -> tuple:
    """Write a block's emissions into the cache.

    KV emissions ``(np, b, L_blk, kv, hd)`` are inserted at sequence position
    ``offset``; state emissions (ssm/rwkv/conv/shift/cross) replace the old
    state wholesale.
    """
    new_slots = []
    for cslot, eslot in zip(cache, emissions):
        ns = dict(cslot)
        for key, val in eslot.items():
            if key in ("k", "v"):
                buf = cslot[key]
                ns[key] = jax.lax.dynamic_update_slice(
                    buf, val.astype(buf.dtype), (0, 0, offset, 0, 0))
            elif key in cslot:
                ns[key] = val.astype(cslot[key].dtype)
        new_slots.append(ns)
    return tuple(new_slots)


def _row_mask(rows, batch: int) -> jnp.ndarray:
    """Normalize ``rows`` (bool lane mask or int lane indices) to (b,) bool."""
    rows = jnp.asarray(rows)
    if rows.dtype == jnp.bool_:
        return rows
    return jnp.zeros((batch,), bool).at[rows].set(True)


def _broadcast_rows(mask, leaf):
    """Reshape a (b,) lane mask to broadcast against a (np, b, ...) leaf."""
    return mask.reshape((1, -1) + (1,) * (leaf.ndim - 2))


def reset(cache: tuple, rows) -> tuple:
    """Zero the selected batch lanes of every cache buffer.

    ``rows``: (b,) bool lane mask (or int lane indices). Neighboring lanes
    are untouched — the primitive that lets a serving scheduler recycle one
    finished lane while the rest of the batch keeps decoding.
    """
    batch = jax.tree_util.tree_leaves(cache)[0].shape[1]
    mask = _row_mask(rows, batch)
    return jax.tree_util.tree_map(
        lambda leaf: jnp.where(_broadcast_rows(mask, leaf),
                               jnp.zeros((), leaf.dtype), leaf), cache)


def commit_rows(cache: tuple, emissions: tuple, offsets, rows) -> tuple:
    """Per-lane :func:`commit`: write emissions only for the selected lanes,
    each at its own sequence ``offset``.

    ``offsets``: scalar or (b,) int — KV insert position per lane;
    ``rows``: (b,) bool lane mask (or int lane indices). Lanes outside
    ``rows`` keep their old cache contents bit-for-bit.
    """
    batch = jax.tree_util.tree_leaves(cache)[0].shape[1]
    mask = _row_mask(rows, batch)
    offsets = jnp.broadcast_to(jnp.asarray(offsets, jnp.int32), (batch,))

    def write_kv(buf, val):
        upd = jax.vmap(
            lambda b_l, v_l, off: jax.lax.dynamic_update_slice(
                b_l, v_l.astype(b_l.dtype), (0, off, 0, 0)),
            in_axes=(1, 1, 0), out_axes=1)(buf, val, offsets)
        return jnp.where(_broadcast_rows(mask, buf), upd, buf)

    new_slots = []
    for cslot, eslot in zip(cache, emissions):
        ns = dict(cslot)
        for key, val in eslot.items():
            if key in ("k", "v"):
                ns[key] = write_kv(cslot[key], val)
            elif key in cslot:
                old = cslot[key]
                ns[key] = jnp.where(_broadcast_rows(mask, old),
                                    val.astype(old.dtype), old)
        new_slots.append(ns)
    return tuple(new_slots)


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(cache))
