"""Unified block-decode engine (paper §4.3).

Every decoding algorithm in this repo — the teacher operating point, the
training-free cache baselines, the CDLM student, and the AR baseline — is
the *same* block-grid loop with three orthogonal knobs, captured by
:class:`DecodeStrategy`:

- ``attn_mode``:     attention visibility during decode
  (``bidirectional`` | ``block_causal`` | ``causal``);
- ``cache_policy``:  what the KV/state cache means
  (``none``: full recompute every step; ``approx-dual``: stale
  prefix/suffix KV refreshed at block boundaries; ``approx-interval``:
  stale KV refreshed every ``spec.cache_refresh_interval`` steps;
  ``exact-commit``: block-causal exact cache with a commit pass at block
  completion; ``ar``: token-level causal cache);
- ``finalize``:      how tokens are finalized inside a block
  (``top1``: one most-confident token per step; ``threshold``: every
  position with confidence >= tau, at least one; ``greedy-next``:
  autoregressive argmax of the next token).

:func:`run_block_loop` executes a strategy over the static block grid and
is jit-compatible (python loop over blocks, ``lax.while_loop`` within a
block). The thin declarations in ``repro.core.sampler`` are bit-identical
to the seed samplers they replaced: same forward-pass sequence, same RNG
split order, same step/call accounting.

:func:`lane_block_forward` is the per-lane variant of the active-block
forward: each batch lane decodes *its own* block offset against its own
cache rows. Block-causal exactness makes lanes fully independent, which is
the primitive the continuous-batching scheduler in ``repro.serving``
builds on (evict a finished lane, reset its cache rows, admit a queued
request mid-flight).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cache as C
from repro.core import diffusion as D
from repro.core import masks
from repro.models import forward, unembed_matrix


class SampleResult(NamedTuple):
    tokens: jnp.ndarray         # (b, prompt+gen) canvas
    steps: jnp.ndarray          # (b,) refinement iterations
    n_model_calls: jnp.ndarray  # scalar, total forward passes
    gen_lengths: jnp.ndarray    # (b,) tokens before EOS


class LaneParams(NamedTuple):
    """Per-lane (= per-request) sampling parameters, threaded through the
    threshold decode loops as runtime ``(b,)`` arrays so one batch can mix
    requests with different knobs without recompiling per combination.

    Selection semantics per lane: ``temperature <= 0`` lanes take the
    greedy argmax, ``temperature > 0`` lanes draw categorically with their
    *own* PRNG key (``key (b, 2)`` uint32, advanced only on the lane's own
    active iterations — see :func:`repro.core.diffusion.split_lane_keys`),
    so every lane decodes bit-identically to its isolated decode regardless
    of batch composition. ``conf_threshold`` is the per-lane τ of the
    threshold finalize rule; ``eos_id`` the per-lane stop token.
    """
    temperature: jnp.ndarray    # (b,) float32
    conf_threshold: jnp.ndarray  # (b,) float32
    eos_id: jnp.ndarray         # (b,) int32
    key: jnp.ndarray            # (b, 2) uint32 per-lane PRNG keys


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    prompt_len: int             # text prompt tokens in the canvas
    gen_len: int
    block_size: int
    conf_threshold: float = 0.9
    temperature: float = 0.0
    early_stop: bool = True
    cache_refresh_interval: int = 8
    attn_impl: str = "auto"
    pos_offset: int = 0         # prefix embeds (VLM patches) before canvas
    # KV memory layout (repro.core.cache.CACHE_LAYOUTS): "dense" per-lane
    # buffers, or "paged" global page pool + per-lane page tables. Paged is
    # only meaningful for the exact-commit policy (the approx policies
    # refresh whole-canvas KV, so every page is live anyway).
    cache_layout: str = "dense"
    # Route greedy candidate selection through the fused unembed +
    # online-softmax kernel (repro.kernels.select): decode forwards skip
    # lm_head and no (b, ·, V) logits tensor is built. Only engages at
    # temperature 0 — sampled decoding needs logits-shaped categorical
    # draws to keep the baseline RNG stream bit-for-bit.
    fused_select: bool = False

    @property
    def n_blocks(self) -> int:
        return self.gen_len // self.block_size

    @property
    def full_prompt_len(self) -> int:
        return self.prompt_len + self.pos_offset


CACHE_POLICIES = ("none", "approx-dual", "approx-interval", "exact-commit",
                  "ar")
FINALIZE_RULES = ("top1", "threshold", "greedy-next")


@dataclasses.dataclass(frozen=True)
class DecodeStrategy:
    """Declarative description of a decoding algorithm."""
    name: str
    attn_mode: str              # masks.BIDIRECTIONAL | BLOCK_CAUSAL | CAUSAL
    cache_policy: str           # see CACHE_POLICIES
    finalize: str               # see FINALIZE_RULES

    def __post_init__(self):
        if self.cache_policy not in CACHE_POLICIES:
            raise ValueError(f"unknown cache policy {self.cache_policy!r}")
        if self.finalize not in FINALIZE_RULES:
            raise ValueError(f"unknown finalize rule {self.finalize!r}")


#: The six decoding algorithms of Tables 1–2 as strategy declarations.
STRATEGIES = {
    # naive DLM teacher: full bidirectional recompute, top-1 per step
    "vanilla": DecodeStrategy("vanilla", masks.BIDIRECTIONAL, "none", "top1"),
    # Fast-dLLM (Parallel): threshold finalization, full recompute
    "fast_dllm": DecodeStrategy("fast_dllm", masks.BIDIRECTIONAL, "none",
                                "threshold"),
    # Fast-dLLM (Par.+D.C.): stale KV refreshed at block boundaries
    "dual_cache": DecodeStrategy("dual_cache", masks.BIDIRECTIONAL,
                                 "approx-dual", "threshold"),
    # dLLM-Cache analog: stale KV refreshed every R steps
    "interval_cache": DecodeStrategy("interval_cache", masks.BIDIRECTIONAL,
                                     "approx-interval", "threshold"),
    # the paper's student: exact block-causal cache + commit pass
    "cdlm": DecodeStrategy("cdlm", masks.BLOCK_CAUSAL, "exact-commit",
                           "threshold"),
    # autoregressive greedy baseline (Fig. 3)
    "ar": DecodeStrategy("ar", masks.CAUSAL, "ar", "greedy-next"),
}


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------
def init_canvas(prompt_tokens, spec: SamplerSpec, cfg: ModelConfig):
    b = prompt_tokens.shape[0]
    gen = jnp.full((b, spec.gen_len), cfg.mask_token_id, prompt_tokens.dtype)
    return jnp.concatenate([prompt_tokens, gen], axis=1)


def _gen_lengths(tokens, spec: SamplerSpec, cfg: ModelConfig, eos_id=None):
    """Tokens before EOS per lane; ``eos_id`` optionally overrides the
    config stop token with a per-lane ``(b,)`` array (per-request eos)."""
    gen = tokens[:, spec.prompt_len:]
    if eos_id is None:
        is_eos = gen == cfg.eos_token_id
    else:
        is_eos = gen == jnp.asarray(eos_id)[:, None]
    has = jnp.any(is_eos, axis=-1)
    first = jnp.argmax(is_eos, axis=-1)
    return jnp.where(has, first, spec.gen_len)


def _block_pos_mask(T: int, start: int, size: int):
    pos = jnp.arange(T)
    return (pos >= start) & (pos < start + size)


def _full_logits(params, tokens, cfg, spec, mode, extras,
                 return_logits=True):
    """Full forward over the canvas (+ prefix embeds); returns the model
    output with logits/hidden sliced back to canvas coordinates."""
    out = forward(params, tokens, cfg=cfg, mode=mode,
                  prompt_len=spec.full_prompt_len, block_size=spec.block_size,
                  attn_impl=spec.attn_impl, return_logits=return_logits,
                  **extras)
    if spec.pos_offset:
        out = out._replace(
            logits=(None if out.logits is None
                    else out.logits[:, spec.pos_offset:]),
            hidden=out.hidden[:, spec.pos_offset:])
    return out


def _dec_extras(extras):
    return {k: v for k, v in extras.items()
            if k not in ("encoder_embeds", "prefix_embeds")}


def _threshold_update(tokens, logits_canvas, bmask, spec, cfg, key, active):
    """Legacy canvas-coordinate threshold update (temperature > 0 only:
    ``jax.random.categorical`` draws bits shaped like its logits, so the
    sampled path must keep canvas-shaped logits for seed RNG bit-compat).
    The greedy path selects in block coordinates — no (b, T, V) canvas."""
    cand, conf = D.confidence_and_candidates(
        logits_canvas, tokens, cfg.mask_token_id, spec.temperature, key)
    sel = D.select_threshold_in_block(conf, bmask[None, :], spec.conf_threshold)
    sel = sel & active[:, None]
    return jnp.where(sel, cand.astype(tokens.dtype), tokens)


def _block_candidates(params, cfg, spec, out, start, block_tokens, key):
    """(cand, conf) for the active block, in block coordinates (b, B).

    ``out`` is the model output of either a block decode (logits/hidden
    already block-shaped) or a full-canvas forward (sliced here).
    ``spec.fused_select`` reads ``out.hidden`` through the fused
    unembed+select kernel (``out.logits`` is None in that mode); the
    baseline path softmaxes ``out.logits``. Bit-identical selection to the
    canvas-coordinate path at temperature 0: softmax/argmax rows are
    independent, and out-of-block positions could never be selected."""
    B = spec.block_size
    if spec.fused_select:
        h = out.hidden
        if h.shape[1] != B:
            h = jax.lax.dynamic_slice_in_dim(h, start, B, 1)
        return D.confidence_and_candidates_fused(
            h, unembed_matrix(params, cfg), block_tokens, cfg.mask_token_id,
            spec.temperature, key, softcap=cfg.final_logit_softcap)
    logits = out.logits
    if logits.shape[1] != B:
        logits = jax.lax.dynamic_slice_in_dim(logits, start, B, 1)
    return D.confidence_and_candidates(logits, block_tokens,
                                       cfg.mask_token_id, spec.temperature,
                                       key)


def _threshold_block_update(params, cfg, spec, tokens, out, start, key,
                            active):
    """Block-coordinate threshold finalization: slice the active block,
    select on (b, B) candidates/confidences, scatter only the finalized
    *tokens* back — the per-step (b, T, V) logits canvas is gone."""
    B = spec.block_size
    bt = jax.lax.dynamic_slice_in_dim(tokens, start, B, 1)
    cand, conf = _block_candidates(params, cfg, spec, out, start, bt, key)
    sel = D.select_threshold_in_block(conf, jnp.ones((1, B), bool),
                                      spec.conf_threshold)
    sel = sel & active[:, None]
    bt = jnp.where(sel, cand.astype(bt.dtype), bt)
    return jax.lax.dynamic_update_slice_in_dim(tokens, bt, start, 1)


def _block_candidates_per_lane(params, cfg, spec, out, start, block_tokens,
                               lanes: LaneParams, subs, *, fused: bool,
                               sampled: bool):
    """(cand, conf) for the active block under per-lane sampling params.

    ``fused`` (all-greedy batches only) routes through the fused
    unembed+select kernel exactly like the scalar path; otherwise
    selection is per-lane: greedy lanes argmax, sampled lanes draw with
    their own key (``subs (b, 2)``)."""
    B = spec.block_size
    if fused:
        h = out.hidden
        if h.shape[1] != B:
            h = jax.lax.dynamic_slice_in_dim(h, start, B, 1)
        return D.confidence_and_candidates_fused(
            h, unembed_matrix(params, cfg), block_tokens, cfg.mask_token_id,
            0.0, None, softcap=cfg.final_logit_softcap)
    logits = out.logits
    if logits.shape[1] != B:
        logits = jax.lax.dynamic_slice_in_dim(logits, start, B, 1)
    return D.confidence_and_candidates_per_lane(
        logits, block_tokens, cfg.mask_token_id, lanes.temperature,
        subs if sampled else None)


def _threshold_lane_update(params, cfg, spec, tokens, out, start, lanes,
                           subs, active, *, fused: bool, sampled: bool):
    """Block-coordinate threshold finalization with per-lane (b,) params:
    per-lane temperature drives greedy-vs-sampled candidates, per-lane τ
    drives the threshold selection."""
    B = spec.block_size
    bt = jax.lax.dynamic_slice_in_dim(tokens, start, B, 1)
    cand, conf = _block_candidates_per_lane(params, cfg, spec, out, start,
                                            bt, lanes, subs, fused=fused,
                                            sampled=sampled)
    sel = D.select_threshold_in_block(conf, jnp.ones((1, B), bool),
                                      lanes.conf_threshold[:, None])
    sel = sel & active[:, None]
    bt = jnp.where(sel, cand.astype(bt.dtype), bt)
    return jax.lax.dynamic_update_slice_in_dim(tokens, bt, start, 1)


def _refresh_cache(params, tokens, cfg, spec, kv_cache, extras):
    """Full bidirectional forward; commit KV for every position. Only the
    emissions are consumed, so the lm_head is skipped outright."""
    out = forward(params, tokens, cfg=cfg, mode=masks.BIDIRECTIONAL,
                  prompt_len=spec.full_prompt_len, block_size=spec.block_size,
                  attn_impl=spec.attn_impl, return_logits=False, **extras)
    return C.commit(kv_cache, out.emissions, 0)


def _commit_any(kv_cache, emissions, offset, b):
    """Layout-agnostic whole-batch commit at a shared offset."""
    if isinstance(kv_cache, C.PagedCache):
        return C.commit_rows(kv_cache, emissions, offset,
                             jnp.ones((b,), bool))
    return C.commit(kv_cache, emissions, offset)


def _init_exact_cache(cfg, b, S, spec: SamplerSpec):
    """Exact-commit cache in the layout ``spec.cache_layout`` selects.

    The paged variant allocates a dense-equivalent pool (every lane can back
    its whole canvas) and assigns pages up front — the single-sequence loop
    is the bit-equivalence harness for the layout; page-at-a-time admission
    lives in the serving engine."""
    if spec.cache_layout == C.DENSE:
        return C.init_cache(cfg, b, S, dtype=cfg.dtype)
    if spec.cache_layout != C.PAGED:
        raise ValueError(f"unknown cache layout {spec.cache_layout!r} "
                         f"(expected one of {C.CACHE_LAYOUTS})")
    page = spec.block_size
    n_tables = -(-S // page)
    paged = C.init_paged_cache(cfg, b, n_tables * page, n_pages=b * n_tables,
                               page_size=page, dtype=cfg.dtype)
    paged, _ = C.alloc(paged, jnp.ones((b,), bool), 0, S)
    return paged


# ---------------------------------------------------------------------------
# Finalization family: top1 (the teacher / trajectory collector)
# ---------------------------------------------------------------------------
def _top1_loop(params, prompt_tokens, *, cfg, spec, strategy, key, extras,
               record_hidden):
    """N = L_g steps, one most-confident token finalized per step.

    With ``record_hidden`` also returns ``finalized_at`` (b, L_g) — the step
    index at which each position was finalized (a compact, exact encoding of
    the monotone trajectory T_x) — and the hidden buffer H (b, L_g, d)."""
    tokens = init_canvas(prompt_tokens, spec, cfg)
    b, T = tokens.shape
    P, B, G = spec.prompt_len, spec.block_size, spec.gen_len
    finalized_at = jnp.full((b, G), -1, jnp.int32)
    hidden_buf = jnp.zeros((b, G, cfg.d_model), jnp.float32)
    step_counter = 0
    # greedy: block-coordinate selection (and, with spec.fused_select, no
    # logits at all); sampled: seed canvas path for RNG bit-compat
    blockwise = spec.temperature <= 0
    fused = spec.fused_select and blockwise

    for blk in range(spec.n_blocks):
        start = P + blk * B
        bmask = _block_pos_mask(T, start, B)
        for _ in range(B):
            key, sub = jax.random.split(key)
            out = _full_logits(params, tokens, cfg, spec, strategy.attn_mode,
                               extras, return_logits=not fused)
            if blockwise:
                bt = jax.lax.dynamic_slice_in_dim(tokens, start, B, 1)
                cand, conf = _block_candidates(params, cfg, spec, out, start,
                                               bt, sub)
                bsel = D.select_topk_in_block(conf, jnp.ones((1, B), bool), 1)
                bt = jnp.where(bsel, cand.astype(bt.dtype), bt)
                tokens = jax.lax.dynamic_update_slice_in_dim(tokens, bt,
                                                             start, 1)
                sel = jax.lax.dynamic_update_slice(
                    jnp.zeros((b, T), bool), bsel, (0, start))
            else:
                cand, conf = D.confidence_and_candidates(
                    out.logits, tokens, cfg.mask_token_id, spec.temperature,
                    sub)
                sel = D.select_topk_in_block(conf, bmask[None, :], 1)
                tokens = jnp.where(sel, cand.astype(tokens.dtype), tokens)
            if record_hidden:
                gen_sel = sel[:, P:]
                finalized_at = jnp.where(gen_sel, step_counter, finalized_at)
                hidden_buf = jnp.where(
                    gen_sel[..., None], out.hidden[:, P:].astype(jnp.float32),
                    hidden_buf)
            step_counter += 1

    steps = jnp.full((b,), step_counter, jnp.int32)
    res = SampleResult(tokens, steps, jnp.asarray(step_counter, jnp.int32),
                       _gen_lengths(tokens, spec, cfg))
    if record_hidden:
        return res, finalized_at, hidden_buf
    return res


# ---------------------------------------------------------------------------
# Finalization family: threshold (Fast-dLLM / cache baselines / CDLM)
# ---------------------------------------------------------------------------
def _threshold_loop(params, prompt_tokens, *, cfg, spec, strategy, key,
                    extras, use_long_window, lane_params=None,
                    lane_sampled=False):
    tokens = init_canvas(prompt_tokens, spec, cfg)
    b, T = tokens.shape
    P, B, off = spec.prompt_len, spec.block_size, spec.pos_offset
    S = T + off
    policy = strategy.cache_policy
    approx = policy in ("approx-dual", "approx-interval")
    dx = _dec_extras(extras)
    R = spec.cache_refresh_interval
    done = jnp.zeros((b,), bool)
    steps = jnp.zeros((b,), jnp.int32)
    # lanes: per-request (b,) params — always block-coordinate selection,
    # per-lane RNG streams (lane_sampled: any lane draws categorically).
    # scalar greedy: block-coordinate selection (and, with
    # spec.fused_select, hidden-only decode forwards); scalar sampled:
    # seed canvas path (RNG compat)
    lanes = lane_params is not None
    blockwise = True if lanes else spec.temperature <= 0
    fused = spec.fused_select and (not lane_sampled if lanes else blockwise)
    key_state = lane_params.key if lanes else key

    if policy == "none":
        kv_cache = None
        calls = jnp.zeros((), jnp.int32)
    elif approx:
        kv_cache = C.init_cache(cfg, b, S, dtype=cfg.dtype)
        kv_cache = _refresh_cache(params, tokens, cfg, spec, kv_cache, extras)
        calls = jnp.ones((), jnp.int32)
    else:  # exact-commit: prefill prompt (+ prefix embeds) block-causally
        kv_cache = _init_exact_cache(cfg, b, S, spec)
        out = forward(params, tokens[:, :P], cfg=cfg, mode=strategy.attn_mode,
                      prompt_len=spec.full_prompt_len, block_size=B,
                      attn_impl=spec.attn_impl, return_logits=False, **extras)
        kv_cache = _commit_any(kv_cache, out.emissions, 0, b)
        calls = jnp.ones((), jnp.int32)

    for blk in range(spec.n_blocks):
        start = P + blk * B                  # canvas coords
        astart = start + off                 # absolute sequence coords
        bmask = _block_pos_mask(T, start, B)
        # approx policies: stale cache entries for the active block itself
        # are invalid — fresh block KV is computed every step.
        cache_valid = ~_block_pos_mask(S, astart, B) if approx else None

        def block_out(tokens, kv_cache):
            block_tokens = jax.lax.dynamic_slice_in_dim(tokens, start, B, 1)
            return forward(params, block_tokens, cfg=cfg,
                           mode=strategy.attn_mode,
                           prompt_len=spec.full_prompt_len, block_size=B,
                           positions=astart + jnp.arange(B), cache=kv_cache,
                           cache_len=astart, cache_valid=cache_valid,
                           use_long_window=use_long_window,
                           attn_impl=spec.attn_impl,
                           return_logits=not fused, **dx)

        if policy == "approx-dual" and blk > 0:
            kv_cache = _refresh_cache(params, tokens, cfg, spec, kv_cache,
                                      extras)
            calls = calls + 1

        def cond(st):
            tokens, kv_cache, steps, calls, key, done, it = st
            masked = jnp.any((tokens == cfg.mask_token_id) & bmask[None, :]
                             & ~done[:, None], axis=-1)
            return jnp.any(masked) & (it < B)

        def body(st):
            tokens, kv_cache, steps, calls, key, done, it = st
            active = jnp.any((tokens == cfg.mask_token_id) & bmask[None, :],
                             axis=-1) & ~done
            if lanes:
                key, sub = D.split_lane_keys(key, active)
            else:
                key, sub = jax.random.split(key)
            if policy == "approx-interval":
                kv_cache = jax.lax.cond(
                    (it % R) == (R - 1),
                    lambda c: _refresh_cache(params, tokens, cfg, spec, c,
                                             extras),
                    lambda c: c, kv_cache)
            if policy == "none":
                out = _full_logits(params, tokens, cfg, spec,
                                   strategy.attn_mode, extras,
                                   return_logits=not fused)
            else:
                out = block_out(tokens, kv_cache)
            if lanes:
                tokens = _threshold_lane_update(params, cfg, spec, tokens,
                                                out, start, lane_params, sub,
                                                active, fused=fused,
                                                sampled=lane_sampled)
            elif blockwise:
                tokens = _threshold_block_update(params, cfg, spec, tokens,
                                                 out, start, sub, active)
            else:
                # sampled decoding: seed-identical canvas-shaped categorical
                if policy == "none":
                    logits_canvas = out.logits
                else:
                    logits_canvas = jnp.zeros((b, T, out.logits.shape[-1]),
                                              out.logits.dtype)
                    logits_canvas = jax.lax.dynamic_update_slice_in_dim(
                        logits_canvas, out.logits, start, 1)
                tokens = _threshold_update(tokens, logits_canvas, bmask, spec,
                                           cfg, sub, active)
            return (tokens, kv_cache, steps + active.astype(jnp.int32),
                    calls + 1, key, done, it + 1)

        tokens, kv_cache, steps, calls, key_state, done, _ = jax.lax.while_loop(
            cond, body,
            (tokens, kv_cache, steps, calls, key_state, done,
             jnp.zeros((), jnp.int32)))

        if policy == "exact-commit":
            # commit pass: recompute the finalized block's KV exactly
            out = block_out(tokens, kv_cache)
            kv_cache = _commit_any(kv_cache, out.emissions, astart, b)
            calls = calls + 1

        if spec.early_stop:
            eos = (lane_params.eos_id[:, None] if lanes
                   else cfg.eos_token_id)
            done = done | jnp.any((tokens == eos) & bmask[None, :], -1)

    return SampleResult(tokens, steps, calls,
                        _gen_lengths(tokens, spec, cfg,
                                     eos_id=(lane_params.eos_id if lanes
                                             else None)))


# ---------------------------------------------------------------------------
# Finalization family: greedy-next (AR baseline / RWKV decode)
# ---------------------------------------------------------------------------
def _greedy_next_loop(params, prompt_tokens, *, cfg, spec, strategy, extras):
    tokens = init_canvas(prompt_tokens, spec, cfg)
    b, T = tokens.shape
    P, off = spec.prompt_len, spec.pos_offset
    S = T + off
    kv_cache = C.init_cache(cfg, b, S, dtype=cfg.dtype)
    out = forward(params, tokens[:, :P], cfg=cfg, mode=strategy.attn_mode,
                  attn_impl=spec.attn_impl, **extras)
    kv_cache = C.commit(kv_cache, out.emissions, 0)
    last_logits = out.logits[:, -1]
    dx = _dec_extras(extras)

    def body(i, st):
        tokens, kv_cache, last_logits, done, steps, calls = st
        pos = P + i
        nxt = jnp.argmax(last_logits, axis=-1).astype(tokens.dtype)
        nxt = jnp.where(done, jnp.asarray(cfg.eos_token_id, tokens.dtype), nxt)
        tokens = jax.lax.dynamic_update_slice(tokens, nxt[:, None], (0, pos))
        steps = steps + (~done).astype(jnp.int32)
        done = done | (nxt == cfg.eos_token_id)
        out = forward(params, nxt[:, None], cfg=cfg, mode=strategy.attn_mode,
                      positions=(pos + off)[None], cache=kv_cache,
                      cache_len=pos + off, attn_impl=spec.attn_impl, **dx)
        kv_cache = C.commit(kv_cache, out.emissions, pos + off)
        return (tokens, kv_cache, out.logits[:, -1], done, steps, calls + 1)

    done = jnp.zeros((b,), bool)
    steps = jnp.zeros((b,), jnp.int32)
    calls = jnp.ones((), jnp.int32)
    tokens, kv_cache, last_logits, done, steps, calls = jax.lax.fori_loop(
        0, spec.gen_len, body,
        (tokens, kv_cache, last_logits, done, steps, calls))

    return SampleResult(tokens, steps, calls, _gen_lengths(tokens, spec, cfg))


# ---------------------------------------------------------------------------
# The unified entry point
# ---------------------------------------------------------------------------
def run_block_loop(params, prompt_tokens, *, cfg: ModelConfig,
                   spec: SamplerSpec, strategy: DecodeStrategy, key=None,
                   extras=None, record_hidden: bool = False,
                   use_long_window: bool = False,
                   lane_params: LaneParams | None = None,
                   lane_sampled: bool = False):
    """Decode ``prompt_tokens`` with ``strategy`` over the static block grid.

    Returns :class:`SampleResult`; with ``record_hidden`` (``top1``
    finalization only) also the trajectory encoding ``(finalized_at, H)``.

    ``lane_params`` switches the threshold loop to per-lane (b,) sampling
    parameters (temperature / conf_threshold / eos / PRNG key per request);
    ``lane_sampled`` is the static flag for whether any lane draws
    categorically (it decides whether logits-bearing forwards are traced).
    Only threshold-finalize strategies support per-lane params.
    """
    extras = extras or {}
    key = key if key is not None else jax.random.PRNGKey(0)
    if lane_params is not None and strategy.finalize != "threshold":
        raise ValueError(
            "per-request sampling params (lane_params) require a "
            f"threshold-finalize strategy; {strategy.name!r} uses "
            f"{strategy.finalize!r}")
    if spec.cache_layout != C.DENSE and strategy.cache_policy != "exact-commit":
        raise ValueError(
            f"cache_layout={spec.cache_layout!r} requires the 'exact-commit' "
            f"cache policy (strategy {strategy.name!r} uses "
            f"{strategy.cache_policy!r}); approx/ar policies rewrite "
            "whole-canvas KV, so paging buys nothing")
    if record_hidden and strategy.finalize != "top1":
        raise ValueError("record_hidden requires the 'top1' finalize rule "
                         f"(strategy {strategy.name!r} uses "
                         f"{strategy.finalize!r})")
    if strategy.finalize == "top1":
        return _top1_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                          strategy=strategy, key=key, extras=extras,
                          record_hidden=record_hidden)
    if strategy.finalize == "threshold":
        return _threshold_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                               strategy=strategy, key=key, extras=extras,
                               use_long_window=use_long_window,
                               lane_params=lane_params,
                               lane_sampled=lane_sampled)
    return _greedy_next_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                             strategy=strategy, extras=extras)


# ---------------------------------------------------------------------------
# Per-lane block decode (the continuous-batching primitive)
# ---------------------------------------------------------------------------
def lane_block_forward(params, tokens, starts, kv_cache, *, cfg: ModelConfig,
                       spec: SamplerSpec, extras=None,
                       use_long_window: bool = False,
                       paged_attention_fn=None,
                       return_hidden: bool = False):
    """Block-causal cached forward where each lane decodes its own block.

    tokens: (b, T) canvases; starts: (b,) canvas coordinate of each lane's
    active block; kv_cache: batch cache — either a dense tuple (leaves
    batched on axis 1) or a :class:`repro.core.cache.PagedCache` (K/V pools
    shared across lanes, page tables batched on axis 0).
    Returns ``(logits (b, B, V), emissions)`` with emissions batched on
    axis 1, ready for :func:`repro.core.cache.commit_rows`. With
    ``return_hidden`` the first element is the post-norm hidden state
    ``(b, B, d)`` instead and the lm_head is skipped — the fused-select
    serving path feeds it straight into ``kernels.select``.

    Exactness: under the block-causal mask a lane's logits depend only on
    its own committed cache rows and its own block, so mixing lanes at
    different block offsets in one batch is loss-free — this is what makes
    continuous block-level batching safe.

    ``paged_attention_fn`` (paged cache only): a
    ``kernels.decode_attn.paged_decode_attention``-shaped kernel that walks
    the page table directly instead of the default dense-gather path (which
    is bit-identical to the dense layout but materializes a per-lane dense
    KV view).
    """
    B, off = spec.block_size, spec.pos_offset
    dx = _dec_extras(extras or {})
    paged = isinstance(kv_cache, C.PagedCache)
    if paged:
        # pools are lane-shared (broadcast under vmap); per-lane state
        # leaves ride on axis 1, the page table on axis 0
        cache_axes = C.PagedCache(
            slots=tuple({k: (None if k in ("k", "v") else 1) for k in slot}
                        for slot in kv_cache.slots),
            page_table=0, page_owner=None)
    else:
        cache_axes = 1

    def one(tok, start, cache_lane):
        astart = start + off
        block_tok = jax.lax.dynamic_slice(tok, (start,), (B,))[None]
        if paged:
            cache1 = tuple(
                {k: (v if k in ("k", "v") else v[:, None])
                 for k, v in slot.items()} for slot in cache_lane.slots)
            pages1 = cache_lane.page_table[None]
        else:
            cache1 = jax.tree_util.tree_map(lambda a: a[:, None], cache_lane)
            pages1 = None
        out = forward(params, block_tok, cfg=cfg, mode=masks.BLOCK_CAUSAL,
                      prompt_len=spec.full_prompt_len, block_size=B,
                      positions=astart + jnp.arange(B), cache=cache1,
                      cache_len=astart, pages=pages1,
                      paged_decode_attention_fn=(paged_attention_fn
                                                 if paged else None),
                      use_long_window=use_long_window,
                      attn_impl=spec.attn_impl,
                      return_logits=not return_hidden, **dx)
        emissions = jax.tree_util.tree_map(lambda a: a[:, 0], out.emissions)
        return (out.hidden[0] if return_hidden else out.logits[0]), emissions

    return jax.vmap(one, in_axes=(0, 0, cache_axes), out_axes=(0, 1))(
        tokens, starts, kv_cache)
