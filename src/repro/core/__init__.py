"""The paper's primary contribution: consistency-distilled block-causal
diffusion language modeling — masks, diffusion process, 3-objective losses,
trajectory collection, exact block-wise caches (with per-lane reset/commit
for continuous batching), the unified block-decode engine (``block_loop``)
and the sampler strategy declarations over it.

NOTE: submodules are imported lazily (``from repro.core import sampler``)
— ``sampler``/``block_loop``/``trajectory`` depend on ``repro.models``
which itself uses ``repro.core.masks``, so eager package imports here
would be circular.
"""
from repro.core import diffusion, losses, masks  # noqa: F401  (leaf modules)
