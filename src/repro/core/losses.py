"""CDLM training objectives (paper §4.2, Eqs. 4–7).

All three losses operate on per-position logits and boolean position masks:

- ``distillation_loss`` — forward KL(p_teacher || q_student) on positions
  newly unmasked between y and y* (U_y). Teacher distributions are
  reconstructed from the stored last-hidden buffer through the (frozen)
  teacher lm_head — the paper's 30× storage trick (App. A.1).
- ``consistency_loss`` — forward KL(q_student(y*) || q_student(y)) on
  positions still masked at y* (S_y), with the y* branch stop-gradiented
  (the consistency-model target network, Song et al. 2023).
- ``dlm_loss`` — the masked-denoising objective (Eq. 6) with 1/t weighting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _masked_mean(per_pos, mask):
    """Mean over selected positions, normalized per example then batched
    (matches the 1/|U_y| inner average in Eqs. 4–5)."""
    mask = mask.astype(jnp.float32)
    per_example = jnp.sum(per_pos * mask, axis=-1) / jnp.maximum(mask.sum(-1), 1.0)
    has_any = (mask.sum(-1) > 0).astype(jnp.float32)
    return jnp.sum(per_example * has_any) / jnp.maximum(has_any.sum(), 1.0)


def forward_kl(p_logits, q_logits):
    """KL(p || q) per position; logits (..., V)."""
    p_logp = jax.nn.log_softmax(p_logits.astype(jnp.float32), axis=-1)
    q_logp = jax.nn.log_softmax(q_logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(p_logp)
    return jnp.sum(p * (p_logp - q_logp), axis=-1)


def reverse_kl(p_logits, q_logits):
    return forward_kl(q_logits, p_logits)


def distillation_loss(student_logits, teacher_logits, newly_unmasked,
                      kl_direction: str = "forward"):
    """Eq. 4. ``newly_unmasked``: bool (b, L) = U_y."""
    teacher_logits = jax.lax.stop_gradient(teacher_logits)
    kl = forward_kl(teacher_logits, student_logits) if kl_direction == "forward" \
        else reverse_kl(teacher_logits, student_logits)
    return _masked_mean(kl, newly_unmasked)


def consistency_loss(student_logits_y, student_logits_ystar, still_masked,
                     kl_direction: str = "forward"):
    """Eq. 5. y* branch is the stop-gradient target q_{phi^-}."""
    target = jax.lax.stop_gradient(student_logits_ystar)
    kl = forward_kl(target, student_logits_y) if kl_direction == "forward" \
        else reverse_kl(target, student_logits_y)
    return _masked_mean(kl, still_masked)


def dlm_loss(logits, targets, masked, t):
    """Eq. 6: -1/t * sum_{i masked} log q(y_i | y_t, x), averaged over batch.

    t: (b,) the per-example masking ratio."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    # one-hot contraction instead of take_along_axis: a gather over the
    # model-sharded vocab dim would all-gather (b, L, V) logits; the einsum
    # reduces per-shard and psums a (b, L) tensor (EXPERIMENTS.md §Perf H1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    tok_logp = jnp.einsum("...v,...v->...", logp, onehot)
    t = jnp.maximum(jnp.asarray(t, jnp.float32), 1e-3)
    per_example = -jnp.sum(tok_logp * masked.astype(jnp.float32), axis=-1) / t
    # normalize by generation length so the scale matches across configs
    return jnp.mean(per_example) / targets.shape[-1]


def cdlm_total(l_distill, l_cons, l_dlm, *, w_distill, w_cons, w_dlm):
    """Eq. 7."""
    return w_distill * l_distill + w_cons * l_cons + w_dlm * l_dlm
