"""Decoding algorithms (paper §4.3 + every baseline in Tables 1–2).

Every sampler here is a thin :class:`~repro.core.block_loop.DecodeStrategy`
declaration over the unified block-decode engine in
``repro.core.block_loop`` — one block-grid loop, parameterized by attention
mode × cache policy × finalization rule. The mapping:

====================  ===============  ================  ============
sampler               attn_mode        cache_policy      finalize
====================  ===============  ================  ============
``vanilla``           bidirectional    none              top1
``fast_dllm``         bidirectional    none              threshold
``dual_cache``        bidirectional    approx-dual       threshold
``interval_cache``    bidirectional    approx-interval   threshold
``cdlm``              block_causal     exact-commit      threshold
``ar``                causal           ar                greedy-next
====================  ===============  ================  ============

All samplers operate on fixed-shape token canvases ``(b, prompt_len+gen_len)``
with the generation span initialized to ``[MASK]`` and are jit-compatible.
Coordinate systems: the token canvas excludes modality-stub prefix
embeddings; ``spec.pos_offset`` (= number of prefix embeds) maps canvas
coordinates to absolute sequence positions.

Every sampler returns ``SampleResult(tokens, steps, n_model_calls,
gen_lengths)`` — ``steps`` counts refinement iterations per sequence (the
paper's "Total Steps"); ``n_model_calls`` counts forward passes, commit
passes and cache refreshes included (nothing hidden).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.core.block_loop import (  # noqa: F401  (re-exported API)
    STRATEGIES,
    DecodeStrategy,
    SampleResult,
    SamplerSpec,
    _gen_lengths,
    init_canvas,
    run_block_loop,
)


def vanilla_blockwise(params, prompt_tokens, *, cfg: ModelConfig,
                      spec: SamplerSpec, key=None, extras=None,
                      record_hidden: bool = False):
    """Alg. 1 teacher decoding: N = L_g steps, one token finalized per step.
    (Also the trajectory collector via ``record_hidden``.)"""
    return run_block_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                          strategy=STRATEGIES["vanilla"], key=key,
                          extras=extras, record_hidden=record_hidden)


def fast_dllm_parallel(params, prompt_tokens, *, cfg: ModelConfig,
                       spec: SamplerSpec, key=None, extras=None):
    """Fast-dLLM (Parallel): threshold finalization, full recompute."""
    return run_block_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                          strategy=STRATEGIES["fast_dllm"], key=key,
                          extras=extras)


def dual_cache(params, prompt_tokens, *, cfg, spec, key=None, extras=None):
    """Fast-dLLM (Par.+D.C.): stale prefix/suffix KV refreshed at block
    boundaries."""
    return run_block_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                          strategy=STRATEGIES["dual_cache"], key=key,
                          extras=extras)


def interval_cache(params, prompt_tokens, *, cfg, spec, key=None, extras=None):
    """dLLM-Cache analog: stale KV refreshed every
    ``spec.cache_refresh_interval`` steps."""
    return run_block_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                          strategy=STRATEGIES["interval_cache"], key=key,
                          extras=extras)


def cdlm(params, prompt_tokens, *, cfg: ModelConfig, spec: SamplerSpec,
         key=None, extras=None, use_long_window: bool = False):
    """The paper's student: exact block-causal KV cache, threshold parallel
    finalization, commit pass at block completion, early stop on EOS."""
    return run_block_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                          strategy=STRATEGIES["cdlm"], key=key, extras=extras,
                          use_long_window=use_long_window)


def ar(params, prompt_tokens, *, cfg: ModelConfig, spec: SamplerSpec,
       key=None, extras=None):
    """Autoregressive greedy decode with KV cache (the AR baselines of
    Fig. 3; also RWKV decode)."""
    return run_block_loop(params, prompt_tokens, cfg=cfg, spec=spec,
                          strategy=STRATEGIES["ar"], key=key, extras=extras)


SAMPLERS = {
    "vanilla": vanilla_blockwise,
    "fast_dllm": fast_dllm_parallel,
    "dual_cache": dual_cache,
    "interval_cache": interval_cache,
    "cdlm": cdlm,
    "ar": ar,
}
