"""Trajectory collection for CDLM training (paper Alg. 1, App. A.1).

The teacher decodes block-wise with ``N = L_g`` steps, finalizing exactly one
top-confidence token per step, at each temperature in the augmentation set.
Because the unmasking process is *monotone*, the full trajectory
``T_x = (x_{t_0}, ..., x_{t_N})`` is stored losslessly as
``(final_tokens, finalized_at)``: state ``y`` at step index ``s`` is
reconstructed by re-masking every position finalized at step >= s. The
hidden-state buffer ``H ∈ R^{L_g × d}`` records the teacher's last hidden
state at each position's finalization moment (the paper's ~30× cheaper
alternative to storing |V|-dim logits).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import CDLMConfig, ModelConfig
from repro.core.sampler import SamplerSpec, vanilla_blockwise


def state_at(final_tokens, finalized_at, step, mask_id: int):
    """Reconstruct trajectory state y_{t_step} from the compact encoding.

    final_tokens/finalized_at: (..., L_g); step: scalar or (...,) int."""
    step = jnp.asarray(step)
    while step.ndim < final_tokens.ndim - 0:
        step = step[..., None] if step.ndim < final_tokens.ndim else step
    revealed = (finalized_at >= 0) & (finalized_at < step)
    return jnp.where(revealed, final_tokens, mask_id)


def block_completion_step(t_start, block_size: int):
    """t_end: the step at which t_start's active block completes (at most B
    steps later; strictly greater than t_start)."""
    return (t_start // block_size + 1) * block_size


def position_sets(finalized_at, t_start, t_end):
    """U_y (newly unmasked between y and y*) and S_y (still masked at y*)."""
    t_start = jnp.asarray(t_start)[..., None]
    t_end = jnp.asarray(t_end)[..., None]
    u = (finalized_at >= t_start) & (finalized_at < t_end)
    s = finalized_at >= t_end
    return u, s


def collect(params, prompts, gt_answers, *, cfg: ModelConfig,
            cdlm: CDLMConfig, key, extras=None) -> Dict[str, jnp.ndarray]:
    """Run Alg. 1 over one batch of prompts for every temperature in the
    augmentation set. Returns stacked arrays with leading dim
    ``len(temperatures) * batch``.

    prompts: (b, prompt_len) int32; gt_answers: (b, gen_len) int32.
    """
    extras = extras or {}
    outs = {"prompt": [], "gt": [], "final": [], "finalized_at": [],
            "hidden": []}
    for tau in cdlm.temperatures:
        key, sub = jax.random.split(key)
        spec = SamplerSpec(prompt_len=prompts.shape[1],
                           gen_len=cdlm.gen_length,
                           block_size=cdlm.block_size,
                           temperature=float(tau), early_stop=False)
        res, finalized_at, hidden = vanilla_blockwise(
            params, prompts, cfg=cfg, spec=spec, key=sub, extras=extras,
            record_hidden=True)
        outs["prompt"].append(prompts)
        outs["gt"].append(gt_answers)
        outs["final"].append(res.tokens[:, prompts.shape[1]:])
        outs["finalized_at"].append(finalized_at)
        outs["hidden"].append(hidden)
    return {k: jnp.concatenate(v, axis=0) for k, v in outs.items()}


def sample_training_pair(dataset: Dict[str, jnp.ndarray], key, batch_size: int,
                         *, cfg: ModelConfig, cdlm: CDLMConfig):
    """Alg. 2 lines 4–6: sample trajectory entries and a (y, y*) state pair.

    Returns a dict with canvases ``y``/``y_star`` (b, P+L_g), position masks
    ``u_mask``/``s_mask`` over the canvas, the teacher hidden slice
    (b, L_g, d) and ground-truth answers (b, L_g)."""
    n = dataset["final"].shape[0]
    G, B = cdlm.gen_length, cdlm.block_size
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (batch_size,), 0, n)
    prompt = dataset["prompt"][idx]
    final = dataset["final"][idx]
    fat = dataset["finalized_at"][idx]
    hidden = dataset["hidden"][idx]
    gt = dataset["gt"][idx]

    t_start = jax.random.randint(k2, (batch_size,), 0, G)
    t_end = jnp.minimum(block_completion_step(t_start, B), G)

    y_gen = state_at(final, fat, t_start[:, None], cfg.mask_token_id)
    ystar_gen = state_at(final, fat, t_end[:, None], cfg.mask_token_id)
    u_mask, s_mask = position_sets(fat, t_start, t_end)

    y = jnp.concatenate([prompt, y_gen], axis=1)
    y_star = jnp.concatenate([prompt, ystar_gen], axis=1)
    pad = jnp.zeros((batch_size, prompt.shape[1]), bool)
    return {
        "y": y, "y_star": y_star,
        "u_mask": jnp.concatenate([pad, u_mask], axis=1),
        "s_mask": jnp.concatenate([pad, s_mask], axis=1),
        "teacher_hidden": hidden,
        "final": final,
        "gt": gt,
        "prompt": prompt,
    }
