"""Masked-diffusion process utilities (paper §3).

The forward process masks tokens independently; the reverse-time transition
``q_{s|t}`` (Eq. 2) preserves unmasked tokens, keeps a masked token masked
w.p. ``s/t`` and unmasks it w.p. ``(t-s)/t`` according to the model's
predictive distribution ``q_{0|t}``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def mask_tokens(key, tokens, t, mask_id: int, maskable=None):
    """Independently mask each token with probability ``t`` (Eq. 6 setup).

    tokens: (..., L) int; t: scalar or (...,) broadcastable masking ratio.
    maskable: optional bool (..., L) restricting which positions may be
    masked (e.g. only the answer span)."""
    u = jax.random.uniform(key, tokens.shape)
    t = jnp.asarray(t)
    while t.ndim < tokens.ndim:
        t = t[..., None]
    m = u < t
    if maskable is not None:
        m = m & maskable
    return jnp.where(m, mask_id, tokens), m


def transition_probs(t: float, s: float, is_masked: bool,
                     p_unmask_token: jnp.ndarray) -> dict:
    """Token-level q_{s|t} probabilities (Eq. 2), for tests/properties.

    Returns {"keep": P(stay as-is), "still_masked": ..., "unmask": vector}.
    """
    assert 0 <= s < t <= 1
    if not is_masked:
        return {"keep": 1.0, "still_masked": 0.0,
                "unmask": jnp.zeros_like(p_unmask_token)}
    return {"keep": 0.0, "still_masked": s / t,
            "unmask": (t - s) / t * p_unmask_token}


def timestep(k: int, n_steps: int) -> float:
    """t_k = 1 - k/N."""
    return 1.0 - k / n_steps


def confidence_and_candidates(logits, tokens, mask_id: int,
                              temperature: float = 0.0,
                              key=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-position candidate token + confidence from ``p_theta(x0|x_t)``.

    Greedy (temperature 0): candidate = argmax, confidence = its prob.
    Sampled: candidate ~ softmax(logits/T), confidence = prob of the sample
    under the temperature-1 distribution (Alg. 1 line 11).
    Unmasked positions get confidence -inf (never re-finalized).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if temperature <= 0.0 or key is None:
        cand = jnp.argmax(logits, axis=-1)
    else:
        cand = jax.random.categorical(key, logits.astype(jnp.float32) / temperature)
    conf = jnp.take_along_axis(probs, cand[..., None], axis=-1)[..., 0]
    is_masked = tokens == mask_id
    conf = jnp.where(is_masked, conf, -jnp.inf)
    return cand, conf


def confidence_and_candidates_fused(hidden, w, tokens, mask_id: int,
                                    temperature: float = 0.0, key=None, *,
                                    softcap=None, impl: str = "auto",
                                    interpret=None):
    """Fused-kernel variant of :func:`confidence_and_candidates`.

    Takes pre-``lm_head`` hidden states ``(..., d)`` plus the unembedding
    matrix ``w (d, V)`` (``models.unembed_matrix``) instead of logits, and
    routes greedy selection through ``repro.kernels.select.fused_select`` —
    unembed, online softmax, argmax and confidence in one vocab-tiled pass,
    so the ``(..., V)`` logits tensor never exists. ``softcap`` is the
    model's final-logit softcap (applied in-kernel, where ``lm_head`` would
    have applied it).

    Sampled decoding (``temperature > 0`` with a key) falls back to dense
    logits + the reference path: ``jax.random.categorical`` draws bits
    shaped like its logits, so only the logits-shaped fallback reproduces
    the baseline RNG stream bit-for-bit.
    """
    from repro.kernels.select import fused_select  # kernels are heavier
    # imports (pallas); keep them out of core's import path until used

    if temperature > 0.0 and key is not None:
        logits = jnp.einsum("...d,dv->...v", hidden, w,
                            preferred_element_type=jnp.float32)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        return confidence_and_candidates(logits, tokens, mask_id,
                                         temperature, key)
    return fused_select(hidden, w, tokens == mask_id, softcap=softcap,
                        impl=impl, interpret=interpret)


def split_lane_keys(keys, active):
    """Advance per-lane PRNG keys, but only for ``active`` lanes.

    keys: (b, 2) uint32 per-lane keys; active: (b,) bool.
    Returns ``(new_keys, subkeys)``. A lane's key stream advances exactly
    once per *active* refinement iteration, so the stream a request sees is
    a function of its own decode history only — independent of batch
    neighbors, scheduler and batch size. Inactive lanes keep their key
    (their subkey is garbage, and must be masked out by the caller).
    """
    pairs = jax.vmap(jax.random.split)(keys)          # (b, 2, 2)
    new_keys = jnp.where(active[:, None], pairs[:, 0], keys)
    return new_keys, pairs[:, 1]


def confidence_and_candidates_per_lane(logits, tokens, mask_id: int,
                                       temperatures, keys=None):
    """Per-lane variant of :func:`confidence_and_candidates`.

    temperatures: (b,) per-lane sampling temperature — lanes with
    ``temperature <= 0`` take the greedy argmax, lanes with
    ``temperature > 0`` draw from ``softmax(logits / T)`` using their *own*
    PRNG key from ``keys (b, 2)`` (vmapped ``jax.random.categorical``, so a
    lane's draw depends only on its own logits and key — one continuous
    batch can mix greedy and sampled lanes while every lane stays
    bit-identical to its isolated decode). Confidence is the probability of
    the candidate under the temperature-1 distribution, as in the scalar
    path; ``keys=None`` skips the draws entirely (all-greedy batch).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    greedy = jnp.argmax(logits, axis=-1)
    if keys is None:
        cand = greedy
    else:
        t = jnp.maximum(temperatures, 1e-6)
        scaled = logits.astype(jnp.float32) / t[:, None, None]
        drawn = jax.vmap(jax.random.categorical)(keys, scaled)
        cand = jnp.where((temperatures > 0.0)[:, None], drawn, greedy)
    conf = jnp.take_along_axis(probs, cand[..., None], axis=-1)[..., 0]
    is_masked = tokens == mask_id
    conf = jnp.where(is_masked, conf, -jnp.inf)
    return cand, conf


def select_topk_in_block(conf, block_mask, k: int = 1):
    """Boolean selection of the top-k confident positions within the active
    block (vanilla low-confidence-remasking unmasks top-1 per step)."""
    masked_conf = jnp.where(block_mask, conf, -jnp.inf)
    if k == 1:
        idx = jnp.argmax(masked_conf, axis=-1)
        sel = jax.nn.one_hot(idx, conf.shape[-1], dtype=bool)
        # nothing to select if the whole block is already finalized
        any_masked = jnp.any(jnp.isfinite(masked_conf), axis=-1, keepdims=True)
        return sel & any_masked
    top_vals, _ = jax.lax.top_k(masked_conf, k)
    thresh = top_vals[..., -1:]
    sel = (masked_conf >= thresh) & jnp.isfinite(masked_conf)
    return sel


def select_threshold_in_block(conf, block_mask, tau):
    """Fast-dLLM / CDLM §4.3: every position with conf >= tau, but always at
    least the single most-confident masked position. ``tau`` may be a scalar
    or a per-lane ``(b, 1)`` array (per-request confidence thresholds)."""
    masked_conf = jnp.where(block_mask, conf, -jnp.inf)
    above = masked_conf >= tau
    top1 = select_topk_in_block(conf, block_mask, 1)
    return above | top1
