"""Attention-visibility builders (paper Fig. 2).

Three modes:

- ``bidirectional``: the teacher DLM — every position attends everywhere.
- ``block_causal``: the CDLM student — a position attends to the prompt, all
  *completed* blocks before its own block, and every position (incl. future)
  of its *own* block. Block index of position p (p >= prompt_len) is
  ``(p - prompt_len) // block_size``; prompt positions form block -1.
- ``causal``: standard AR mask (RWKV-style backbones, AR baselines).

Masks are never materialized at full L×L unless the caller asks: everything
is expressed as a predicate over (q_positions, kv_positions) so chunked/flash
attention can evaluate visibility tile-by-tile.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax.numpy as jnp

BIDIRECTIONAL = "bidirectional"
BLOCK_CAUSAL = "block_causal"
CAUSAL = "causal"

NEG_INF = -1e30  # finite "minus infinity" keeps softmax NaN-free on empty rows


def block_index(pos, prompt_len: int, block_size: int):
    """Block id of each position; prompt (pos < prompt_len) -> -1."""
    pos = jnp.asarray(pos)
    blk = (pos - prompt_len) // block_size
    return jnp.where(pos < prompt_len, -1, blk)


def visible(
    q_pos,
    kv_pos,
    *,
    mode: str,
    prompt_len: int = 0,
    block_size: int = 1,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Boolean visibility matrix of shape (len(q_pos), len(kv_pos)).

    ``window`` intersects a sliding window: for ``causal`` it is the usual
    backward window ``0 <= q-k < window``; for (block-)bidirectional modes it
    is symmetric ``|q-k| < window`` so within-block future positions stay
    visible (gemma2 local layers under the CDLM student mask).
    """
    q = jnp.asarray(q_pos)[:, None]
    k = jnp.asarray(kv_pos)[None, :]
    if mode == BIDIRECTIONAL:
        vis = jnp.ones((q.shape[0], k.shape[1]), dtype=bool)
    elif mode == CAUSAL:
        vis = k <= q
    elif mode == BLOCK_CAUSAL:
        qb = block_index(q, prompt_len, block_size)
        kb = block_index(k, prompt_len, block_size)
        vis = kb <= qb
    else:
        raise ValueError(f"unknown mask mode {mode!r}")
    if window is not None:
        if mode == CAUSAL:
            vis = vis & (q - k < window)
        else:
            vis = vis & (jnp.abs(q - k) < window)
    return vis


def bias_from_visible(vis: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.where(vis, jnp.zeros((), dtype), jnp.full((), NEG_INF, dtype))


def make_bias_fn(
    *,
    mode: str,
    prompt_len: int = 0,
    block_size: int = 1,
    window: Optional[int] = None,
    kv_valid_len=None,
):
    """Returns ``f(q_pos, kv_pos) -> additive bias (q, k)`` for flash/chunked
    attention. ``kv_valid_len`` (scalar) additionally hides cache slots at or
    beyond the currently-filled cache length."""

    def f(q_pos, kv_pos):
        vis = visible(q_pos, kv_pos, mode=mode, prompt_len=prompt_len,
                      block_size=block_size, window=window)
        if kv_valid_len is not None:
            vis = vis & (jnp.asarray(kv_pos)[None, :] < kv_valid_len)
        return bias_from_visible(vis)

    return f


def full_bias(
    seq_len: int,
    *,
    mode: str,
    prompt_len: int = 0,
    block_size: int = 1,
    window: Optional[int] = None,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """(seq, seq) additive bias — only for short sequences / tests."""
    pos = jnp.arange(seq_len)
    return bias_from_visible(
        visible(pos, pos, mode=mode, prompt_len=prompt_len,
                block_size=block_size, window=window), dtype)


block_causal_bias = partial(full_bias, mode=BLOCK_CAUSAL)
bidirectional_bias = partial(full_bias, mode=BIDIRECTIONAL)
causal_bias = partial(full_bias, mode=CAUSAL)
