"""Jit-able training step builders.

- ``dlm_pretrain_step``  — Eq. 6 masked-denoising SFT of the bidirectional
  teacher (how Dream/LLaDA-style DLMs are trained at toy scale).
- ``cdlm_step``          — Alg. 2: the paper's 3-objective fine-tune of the
  block-causal student (full-FT or LoRA).
- ``ar_step``            — next-token loss (RWKV6 / AR baseline training).

Each returns ``(loss, metrics)``-producing closures suitable for
``jax.value_and_grad`` + the AdamW update, and a convenience ``make_*``
that wires optimizer and jit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import CDLMConfig, ModelConfig, TrainConfig
from repro.core import diffusion as D
from repro.core import losses as LS
from repro.core import masks
from repro.models import forward
from repro.models import layers as L
from repro.models import lora as LoRA
from repro.optim import adamw


# ---------------------------------------------------------------------------
# Teacher pretrain (Eq. 6)
# ---------------------------------------------------------------------------
def dlm_pretrain_loss(params, batch, key, *, cfg: ModelConfig,
                      mode: str = masks.BIDIRECTIONAL, block_size: int = 1,
                      remat: bool = False, **fwd_kw):
    """batch: prompt (b, P), answer (b, G), maskable (b, G) bool."""
    prompt, answer, maskable = batch["prompt"], batch["answer"], batch["maskable"]
    b, P = prompt.shape
    k1, k2 = jax.random.split(key)
    t = jax.random.uniform(k1, (b,), minval=0.05, maxval=1.0)
    masked_answer, m = D.mask_tokens(k2, answer, t, cfg.mask_token_id, maskable)
    canvas = jnp.concatenate([prompt, masked_answer], axis=1)
    out = forward(params, canvas, cfg=cfg, mode=mode, prompt_len=P,
                  block_size=block_size, remat=remat, **fwd_kw)
    loss = LS.dlm_loss(out.logits[:, P:], answer, m, t)
    total = loss + cfg.router_aux_weight * out.aux_loss
    return total, {"dlm_loss": loss, "aux": out.aux_loss}


def make_dlm_pretrain_step(cfg: ModelConfig, tcfg: TrainConfig,
                           mode: str = masks.BIDIRECTIONAL,
                           block_size: int = 1):
    lr_fn = adamw.make_lr_fn(tcfg)

    @jax.jit
    def step(params, opt_state, batch, key):
        (loss, metrics), grads = jax.value_and_grad(
            dlm_pretrain_loss, has_aux=True)(
                params, batch, key, cfg=cfg, mode=mode, block_size=block_size,
                remat=tcfg.remat)
        params, opt_state, om = adamw.update(grads, opt_state, params, tcfg, lr_fn)
        return params, opt_state, {**metrics, **om, "loss": loss}

    return step


# ---------------------------------------------------------------------------
# AR training (RWKV6 / AR baseline)
# ---------------------------------------------------------------------------
def ar_loss(params, batch, key, *, cfg: ModelConfig, remat: bool = False,
            **fwd_kw):
    prompt, answer = batch["prompt"], batch["answer"]
    b, P = prompt.shape
    canvas = jnp.concatenate([prompt, answer], axis=1)
    out = forward(params, canvas[:, :-1], cfg=cfg, mode=masks.CAUSAL,
                  remat=remat, **fwd_kw)
    targets = canvas[:, 1:]
    logp = jax.nn.log_softmax(out.logits.astype(jnp.float32), axis=-1)
    tok = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    # train on the answer span only (SFT)
    w = jnp.concatenate([jnp.zeros((b, P - 1)), batch["maskable"].astype(jnp.float32)],
                        axis=1)
    loss = -jnp.sum(tok * w) / jnp.maximum(w.sum(), 1.0)
    total = loss + cfg.router_aux_weight * out.aux_loss
    return total, {"ar_loss": loss, "aux": out.aux_loss}


def make_ar_step(cfg: ModelConfig, tcfg: TrainConfig):
    lr_fn = adamw.make_lr_fn(tcfg)

    @jax.jit
    def step(params, opt_state, batch, key):
        (loss, metrics), grads = jax.value_and_grad(ar_loss, has_aux=True)(
            params, batch, key, cfg=cfg, remat=tcfg.remat)
        params, opt_state, om = adamw.update(grads, opt_state, params, tcfg, lr_fn)
        return params, opt_state, {**metrics, **om, "loss": loss}

    return step


# ---------------------------------------------------------------------------
# CDLM (Alg. 2) — the paper's objective
# ---------------------------------------------------------------------------
def cdlm_loss(trainable, static_params, batch, key, *, cfg: ModelConfig,
              cdlm: CDLMConfig, teacher_head, use_lora: bool,
              lora_rank: int = 32, lora_alpha: float = 32.0,
              remat: bool = False, student_mode: str = masks.BLOCK_CAUSAL,
              extras=None, efficient_loss: bool = False, **fwd_kw):
    """Eq. 7 total objective.

    trainable: LoRA tree (if use_lora) or the full student params.
    static_params: base weights when LoRA is used (ignored otherwise).
    teacher_head: frozen teacher embed/head params for reconstructing
    teacher distributions from the stored hidden buffer (App. A.1).
    batch: output of ``trajectory.sample_training_pair`` plus
    "t"/"dlm_key" handled internally.
    """
    if use_lora:
        params = LoRA.merge(static_params, trainable, lora_alpha, lora_rank)
    else:
        params = trainable

    extras = extras or {}
    off = (extras["prefix_embeds"].shape[1]
           if "prefix_embeds" in extras else 0)
    P = batch["prompt"].shape[1]
    B = cdlm.block_size
    kw = dict(cfg=cfg, mode=student_mode, prompt_len=off + P, block_size=B,
              remat=remat, **extras, **fwd_kw)
    if efficient_loss:
        # §Perf iteration: lm_head over the generation span only — the three
        # objectives never read prompt logits (exact, halves (b, L, V)).
        G = batch["y"].shape[1] - P
        kw["logits_slice"] = (off + P, off + P + G)

    # (i) student at y
    out_y = forward(params, batch["y"], **kw)
    # (ii) student at y* — the stop-gradient consistency target q_{phi^-}
    out_ystar = forward(params, batch["y_star"], **kw)

    if efficient_loss:
        logits_y, logits_ystar = out_y.logits, out_ystar.logits
    else:
        logits_y = out_y.logits[:, off + P:]
        logits_ystar = out_ystar.logits[:, off + P:]
    u_mask = batch["u_mask"][:, P:]
    s_mask = batch["s_mask"][:, P:]

    # teacher distributions from the hidden buffer through the frozen head
    teacher_logits = L.lm_head(teacher_head, batch["teacher_hidden"], cfg)

    l_distill = LS.distillation_loss(logits_y, teacher_logits, u_mask,
                                     cdlm.kl_direction)
    l_cons = LS.consistency_loss(logits_y, logits_ystar, s_mask,
                                 cdlm.kl_direction)

    # (iii) DLM loss on ground-truth text
    k1, k2 = jax.random.split(key)
    b = batch["gt"].shape[0]
    t = jax.random.uniform(k1, (b,), minval=0.05, maxval=1.0)
    masked_gt, m = D.mask_tokens(k2, batch["gt"], t, cfg.mask_token_id,
                                 batch.get("gt_maskable"))
    canvas = jnp.concatenate([batch["prompt"], masked_gt], axis=1)
    out_dlm = forward(params, canvas, **kw)
    dlm_logits = (out_dlm.logits if efficient_loss
                  else out_dlm.logits[:, off + P:])
    l_dlm = LS.dlm_loss(dlm_logits, batch["gt"], m, t)

    total = LS.cdlm_total(l_distill, l_cons, l_dlm, w_distill=cdlm.w_distill,
                          w_cons=cdlm.w_cons, w_dlm=cdlm.w_dlm)
    aux = out_y.aux_loss + out_ystar.aux_loss + out_dlm.aux_loss
    total = total + cfg.router_aux_weight * aux
    return total, {"distill": l_distill, "cons": l_cons, "dlm": l_dlm,
                   "aux": aux}


def make_cdlm_step(cfg: ModelConfig, cdlm: CDLMConfig, tcfg: TrainConfig,
                   student_mode: str = masks.BLOCK_CAUSAL):
    lr_fn = adamw.make_lr_fn(tcfg)

    @jax.jit
    def step(trainable, static_params, teacher_head, opt_state, batch, key):
        (loss, metrics), grads = jax.value_and_grad(cdlm_loss, has_aux=True)(
            trainable, static_params, batch, key, cfg=cfg, cdlm=cdlm,
            teacher_head=teacher_head, use_lora=tcfg.use_lora,
            lora_rank=tcfg.lora_rank, lora_alpha=tcfg.lora_alpha,
            remat=tcfg.remat, student_mode=student_mode)
        trainable, opt_state, om = adamw.update(grads, opt_state, trainable,
                                                tcfg, lr_fn)
        return trainable, opt_state, {**metrics, **om, "loss": loss}

    return step
