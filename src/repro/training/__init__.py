from repro.training import steps, trainer  # noqa: F401
