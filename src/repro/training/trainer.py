"""High-level training drivers: teacher pretrain → trajectory collection →
CDLM student distillation — the full paper pipeline at any scale."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CDLMConfig, ModelConfig, TrainConfig
from repro.core import masks, trajectory
from repro.data import Corpus, answer_mask
from repro.models import init_model
from repro.models import lora as LoRA
from repro.optim import adamw
from repro.training import steps as S


def _log(step, metrics, every=50, t0=None):
    if step % every == 0:
        ms = {k: float(v) for k, v in metrics.items()}
        extra = f" ({time.time()-t0:.0f}s)" if t0 else ""
        print(f"  step {step:5d}  " +
              "  ".join(f"{k}={v:.4f}" for k, v in sorted(ms.items())) + extra)


def train_teacher(cfg: ModelConfig, corpus: Corpus, tcfg: TrainConfig,
                  *, mode: str = masks.BIDIRECTIONAL, block_size: int = 1,
                  seed: int = 0, verbose: bool = True):
    """Masked-denoising SFT of the teacher DLM (or block-causal student-form
    for causal-state backbones like Jamba, per DESIGN.md §5)."""
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    opt = adamw.init(params)
    step_fn = S.make_dlm_pretrain_step(cfg, tcfg, mode=mode,
                                       block_size=block_size)
    t0 = time.time()
    it = corpus.batches(tcfg.batch_size, seed=seed, epochs=10_000)
    for i in range(tcfg.steps):
        batch = next(it)
        jbatch = {"prompt": jnp.asarray(batch["prompt"]),
                  "answer": jnp.asarray(batch["answer"]),
                  "maskable": jnp.asarray(answer_mask(batch["answer"]))}
        key, sub = jax.random.split(key)
        params, opt, metrics = step_fn(params, opt, jbatch, sub)
        if verbose:
            _log(i, metrics, t0=t0)
    return params


def train_ar(cfg: ModelConfig, corpus: Corpus, tcfg: TrainConfig,
             *, seed: int = 0, verbose: bool = True):
    key = jax.random.PRNGKey(seed)
    params = init_model(key, cfg)
    opt = adamw.init(params)
    step_fn = S.make_ar_step(cfg, tcfg)
    t0 = time.time()
    it = corpus.batches(tcfg.batch_size, seed=seed, epochs=10_000)
    for i in range(tcfg.steps):
        batch = next(it)
        jbatch = {"prompt": jnp.asarray(batch["prompt"]),
                  "answer": jnp.asarray(batch["answer"]),
                  "maskable": jnp.asarray(answer_mask(batch["answer"]))}
        key, sub = jax.random.split(key)
        params, opt, metrics = step_fn(params, opt, jbatch, sub)
        if verbose:
            _log(i, metrics, t0=t0)
    return params


def collect_dataset(teacher_params, cfg: ModelConfig, cdlm: CDLMConfig,
                    corpus: Corpus, *, n_examples: int, batch: int = 16,
                    seed: int = 0, extras=None, verbose: bool = True):
    """Alg. 1 over the corpus (jitted per batch)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    collect_jit = jax.jit(
        lambda p, pr, gt, k: trajectory.collect(p, pr, gt, cfg=cfg, cdlm=cdlm,
                                                key=k, extras=extras))
    done = 0
    for b in corpus.batches(batch, seed=seed, epochs=100):
        if done >= n_examples:
            break
        key, sub = jax.random.split(key)
        out = collect_jit(teacher_params, jnp.asarray(b["prompt"]),
                          jnp.asarray(b["answer"]), sub)
        chunks.append(jax.device_get(out))
        done += batch
        if verbose and done % (batch * 4) == 0:
            print(f"  collected {done}/{n_examples} prompts "
                  f"(x{len(cdlm.temperatures)} temps)")
    return {k: jnp.concatenate([np.asarray(c[k]) for c in chunks], axis=0)
            for k in chunks[0]}


def train_student(teacher_params, dataset, cfg: ModelConfig,
                  cdlm: CDLMConfig, tcfg: TrainConfig, *, seed: int = 0,
                  student_mode: str = masks.BLOCK_CAUSAL,
                  verbose: bool = True):
    """Alg. 2. Student initialized from teacher weights (paper §4.1);
    optionally LoRA. Returns merged student params."""
    key = jax.random.PRNGKey(seed + 1)
    teacher_head = jax.tree_util.tree_map(jnp.copy, teacher_params["embed"])

    if tcfg.use_lora:
        trainable = LoRA.init_lora(key, teacher_params, rank=tcfg.lora_rank)
        static = teacher_params
    else:
        trainable = jax.tree_util.tree_map(jnp.copy, teacher_params)
        static = jax.tree_util.tree_map(lambda x: x, teacher_params)  # unused

    opt = adamw.init(trainable)
    step_fn = S.make_cdlm_step(cfg, cdlm, tcfg, student_mode=student_mode)
    sample_jit = jax.jit(lambda k: trajectory.sample_training_pair(
        dataset, k, tcfg.batch_size, cfg=cfg, cdlm=cdlm))

    t0 = time.time()
    for i in range(tcfg.steps):
        key, k1, k2 = jax.random.split(key, 3)
        batch = sample_jit(k1)
        trainable, opt, metrics = step_fn(trainable, static, teacher_head,
                                          opt, batch, k2)
        if verbose:
            _log(i, metrics, t0=t0)

    if tcfg.use_lora:
        return LoRA.merge(static, trainable, tcfg.lora_alpha, tcfg.lora_rank)
    return trainable
