PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-fast bench

# tier-1 suite (ROADMAP.md): must stay green
verify:
	$(PYTHON) -m pytest -x -q

# fast subset: skips the slow toy-scale e2e training pipeline; exercises the
# hypothesis-optional fallback path when hypothesis is not installed
verify-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

bench:
	$(PYTHON) -m benchmarks.run
