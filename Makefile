PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-fast bench bench-smoke bench-gate serve-smoke lint

# tier-1 suite (ROADMAP.md): must stay green
verify:
	$(PYTHON) -m pytest -x -q

# fast subset: skips the slow toy-scale e2e training pipeline; exercises the
# hypothesis-optional fallback path when hypothesis is not installed
verify-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

bench:
	$(PYTHON) -m benchmarks.run

# CI-sized benchmarks: random-init params, tiny shapes; write
# BENCH_serving.json + BENCH_kernels.json (uploaded as artifacts by the
# bench-smoke job so the perf trajectory accumulates per PR)
bench-smoke:
	$(PYTHON) -m benchmarks.bench_serving --smoke --json BENCH_serving.json
	$(PYTHON) -m benchmarks.bench_kernels --smoke --json BENCH_kernels.json

# regression ratchet: run the smoke benches, gate the tracked metrics
# against the last line of BENCH_trajectory.jsonl (>10% regression fails),
# and record the run only once the gate passes (CI: bench-trajectory job)
bench-gate: bench-smoke
	$(PYTHON) -m benchmarks.trajectory gate \
		--kernels BENCH_kernels.json --serving BENCH_serving.json
	$(PYTHON) -m benchmarks.trajectory append \
		--kernels BENCH_kernels.json --serving BENCH_serving.json

# HTTP serving smoke: boot the stdlib /v1/completions frontend on a tiny
# random-init engine, run one streamed + one non-streamed completion via
# urllib, assert token-identical to Engine.generate (CI: serve-smoke job)
serve-smoke:
	$(PYTHON) -m benchmarks.serve_smoke

# requires ruff (pip install ruff); rules configured in pyproject.toml
lint:
	ruff check .
