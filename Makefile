PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-fast bench bench-smoke lint

# tier-1 suite (ROADMAP.md): must stay green
verify:
	$(PYTHON) -m pytest -x -q

# fast subset: skips the slow toy-scale e2e training pipeline; exercises the
# hypothesis-optional fallback path when hypothesis is not installed
verify-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

bench:
	$(PYTHON) -m benchmarks.run

# CI-sized serving benchmark: random-init params, tiny trace; writes
# BENCH_serving.json (uploaded as an artifact by the bench-smoke job)
bench-smoke:
	$(PYTHON) -m benchmarks.bench_serving --smoke --json BENCH_serving.json

# requires ruff (pip install ruff); rules configured in pyproject.toml
lint:
	ruff check .
