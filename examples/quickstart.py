"""Quickstart: the whole CDLM pipeline in ~3 minutes on CPU.

1. pretrain a tiny bidirectional teacher DLM on the synthetic sort task;
2. collect Alg.-1 teacher trajectories (+ hidden-state buffer);
3. distill the block-causal CDLM student with the 3-objective loss;
4. compare vanilla teacher decoding vs CDLM student decoding.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CDLMConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.sampler import SamplerSpec, cdlm, vanilla_blockwise
from repro.data import Corpus, TaskSpec
from repro.data.synthetic import score
from repro.training import trainer


def main():
    t0 = time.time()
    cfg = get_config("qwen2-0.5b").reduced(
        n_layers=2, d_model=128, d_ff=256, vocab_size=128, mask_token_id=127)
    task = TaskSpec("sort", vocab_size=128, prompt_len=10, gen_len=10,
                    sort_k=8, sort_range=24)
    cdlm_cfg = CDLMConfig(block_size=5, gen_length=10, prompt_length=10,
                          temperatures=(0.0,))
    corpus = Corpus(task, 768, seed=0)

    print("[1/4] pretraining bidirectional teacher (Eq. 6)...")
    tcfg = TrainConfig(learning_rate=2e-3, steps=600, batch_size=64,
                       remat=False)
    teacher = trainer.train_teacher(cfg, corpus, tcfg, verbose=False)

    print("[2/4] collecting teacher trajectories (Alg. 1)... "
          f"({time.time()-t0:.0f}s)")
    ds = trainer.collect_dataset(teacher, cfg, cdlm_cfg, corpus,
                                 n_examples=128, batch=64, verbose=False)

    print("[3/4] distilling block-causal CDLM student (Alg. 2)... "
          f"({time.time()-t0:.0f}s)")
    scfg = dataclasses.replace(tcfg, steps=250, learning_rate=5e-4)
    student = trainer.train_student(teacher, ds, cfg, cdlm_cfg, scfg,
                                    verbose=False)

    print(f"[4/4] evaluating... ({time.time()-t0:.0f}s)")
    ev = corpus.eval_batch(64)
    prompts = jnp.asarray(ev["prompt"])
    spec = SamplerSpec(prompt_len=10, gen_len=10, block_size=5,
                       conf_threshold=0.9)
    rt = jax.jit(lambda p, x: vanilla_blockwise(p, x, cfg=cfg, spec=spec))(
        teacher, prompts)
    rs = jax.jit(lambda p, x: cdlm(p, x, cfg=cfg, spec=spec))(
        student, prompts)
    st = score(ev["prompt"], np.asarray(rt.tokens), 10, task)
    ss = score(ev["prompt"], np.asarray(rs.tokens), 10, task)
    print(f"\nteacher (vanilla, no cache): score={st:.2f} "
          f"steps={float(rt.steps.mean()):.1f}")
    print(f"student (CDLM, KV cache):    score={ss:.2f} "
          f"steps={float(rs.steps.mean()):.1f}  "
          f"<- {float(rt.steps.mean())/max(float(rs.steps.mean()),1e-9):.1f}x fewer steps")
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
