"""End-to-end serving driver (the paper is an inference-acceleration paper,
so this is the dictated e2e example): serve a small CDLM model with batched
requests through the serving engines, reporting the paper's efficiency
columns for every sampler strategy.

Every sampler is a ``DecodeStrategy`` declaration over the unified
block-decode engine (``repro.core.block_loop``); the final row runs the
CDLM strategy under the **continuous block-level batching** scheduler
(``repro.serving.ContinuousEngine``): a persistent decode batch where
finished lanes are evicted at block boundaries, their cache rows reset,
and queued requests admitted mid-flight. Its API mirrors ``Engine``
(``warmup()`` / ``generate(requests)``) with two extra per-request knobs —
``Request.max_tokens`` (generation cap, rounded up to a block) and
``Request.arrival_s`` (trace replay offset) — and true per-request
latency/queueing in each ``Response``.

Both engines expose the request-level incremental API
(``add_request()`` / ``step()`` / ``stream()`` / ``abort()``) with
per-request ``SamplingParams``; ``--stream`` demos block-at-a-time
streaming (blocks print the moment they commit — block-causal
finalization means a printed block never changes), and ``--http`` boots
the stdlib HTTP frontend (OpenAI-style ``/v1/completions`` with SSE,
``/healthz``, ``/metrics``) over the CDLM student.

    PYTHONPATH=src python examples/serve_blockwise.py [--sampler cdlm]
    PYTHONPATH=src python examples/serve_blockwise.py --stream
    PYTHONPATH=src python examples/serve_blockwise.py --http --port 8000
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import time

import numpy as np

from benchmarks import common
from repro.configs.base import ServeConfig
from repro.data.synthetic import verify
from repro.serving import Request, efficiency_report, make_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sampler", default="all",
                    choices=["all", "vanilla", "fast_dllm", "dual_cache",
                             "interval_cache", "cdlm"])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--stream", action="store_true",
                    help="demo exact block-at-a-time streaming through the "
                         "continuous engine (cdlm student)")
    ap.add_argument("--http", action="store_true",
                    help="serve the cdlm student over HTTP "
                         "(/v1/completions + SSE) instead of the table")
    ap.add_argument("--port", type=int, default=8000)
    args = ap.parse_args()

    print("loading/training assets (cached under experiments/bench_assets)...")
    teacher = common.get_teacher()
    student = common.get_student(teacher)
    ev = common.corpus().eval_batch(args.requests)
    reqs = [Request(prompt=p, id=i) for i, p in enumerate(ev["prompt"])]

    if args.http or args.stream:
        serve = ServeConfig(max_batch=args.batch,
                            block_size=common.CDLM_CFG.block_size,
                            gen_length=common.TASK.gen_len, sampler="cdlm",
                            scheduler="continuous")
        eng = make_engine(student, common.CFG, serve,
                          prompt_len=common.TASK.prompt_len)
        eng.warmup(per_request=args.http)
        if args.http:
            from repro.serving.server import serve_http
            print(f"serving /v1/completions on http://127.0.0.1:{args.port} "
                  f"(prompt_len={common.TASK.prompt_len}) — Ctrl-C to stop")
            serve_http(eng, "127.0.0.1", args.port)
            return
        print("streaming blocks as they commit (id:block -> tokens):")
        for ev_ in eng.stream(reqs[:args.batch + 2]):
            tag = " <done>" if ev_.finished else ""
            print(f"  {ev_.request_id}:{ev_.index} -> "
                  f"{np.asarray(ev_.tokens).tolist()}{tag}")
        return

    samplers = (["vanilla", "fast_dllm", "dual_cache", "interval_cache",
                 "cdlm"] if args.sampler == "all" else [args.sampler])
    rows = [(name, "static") for name in samplers]
    if args.sampler in ("all", "cdlm"):
        rows.append(("cdlm", "continuous"))

    # TPS is total served tokens / wall-clock for the whole request set, so
    # the column is comparable across schedulers (per-request latency_s
    # means different things: compute share for static, arrival->completion
    # including queueing for continuous).
    print(f"\n{'sampler':16s} {'sched':11s} {'TPS':>8} {'lat(ms)':>9} "
          f"{'steps':>7} {'genlen':>7} {'score':>6}")
    for name, sched in rows:
        params = student if name == "cdlm" else teacher
        serve = ServeConfig(max_batch=args.batch,
                            block_size=common.CDLM_CFG.block_size,
                            gen_length=common.TASK.gen_len, sampler=name,
                            scheduler=sched)
        eng = make_engine(params, common.CFG, serve,
                          prompt_len=common.TASK.prompt_len)
        eng.warmup()
        t0 = time.perf_counter()
        resp = eng.generate(reqs)
        wall = time.perf_counter() - t0
        rep = efficiency_report(resp)
        tps = sum(r.gen_length for r in resp) / wall if wall else 0.0
        ok = np.mean([verify(ev["prompt"][r.id], r.tokens, common.TASK)
                      for r in resp])
        print(f"{name:16s} {sched:11s} {tps:>8.0f} "
              f"{rep['latency_s']*1e3:>9.2f} {rep['steps']:>7.1f} "
              f"{rep['gen_length']:>7.1f} {ok:>6.2f}")


if __name__ == "__main__":
    main()
