"""End-to-end serving driver (the paper is an inference-acceleration paper,
so this is the dictated e2e example): serve a small CDLM model with batched
requests through the Engine, reporting the paper's efficiency columns for
every sampler.

    PYTHONPATH=src python examples/serve_blockwise.py [--sampler cdlm]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks import common
from repro.configs.base import ServeConfig
from repro.data.synthetic import score, verify
from repro.serving import Engine, Request, efficiency_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sampler", default="all",
                    choices=["all", "vanilla", "fast_dllm", "dual_cache",
                             "interval_cache", "cdlm"])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    print("loading/training assets (cached under experiments/bench_assets)...")
    teacher = common.get_teacher()
    student = common.get_student(teacher)
    ev = common.corpus().eval_batch(args.requests)
    reqs = [Request(prompt=p, id=i) for i, p in enumerate(ev["prompt"])]

    samplers = (["vanilla", "fast_dllm", "dual_cache", "interval_cache",
                 "cdlm"] if args.sampler == "all" else [args.sampler])
    print(f"\n{'sampler':16s} {'TPS':>8} {'lat(ms)':>9} {'steps':>7} "
          f"{'genlen':>7} {'score':>6}")
    for name in samplers:
        params = student if name == "cdlm" else teacher
        serve = ServeConfig(max_batch=args.batch,
                            block_size=common.CDLM_CFG.block_size,
                            gen_length=common.TASK.gen_len, sampler=name)
        eng = Engine(params, common.CFG, serve,
                     prompt_len=common.TASK.prompt_len)
        eng.warmup()
        resp = eng.generate(reqs)
        rep = efficiency_report(resp)
        ok = np.mean([verify(ev["prompt"][r.id], r.tokens, common.TASK)
                      for r in resp])
        print(f"{name:16s} {rep['tps']:>8.0f} {rep['latency_s']*1e3:>9.2f} "
              f"{rep['steps']:>7.1f} {rep['gen_length']:>7.1f} {ok:>6.2f}")


if __name__ == "__main__":
    main()
