"""End-to-end serving driver (the paper is an inference-acceleration paper,
so this is the dictated e2e example): serve a small CDLM model with batched
requests through the serving engines, reporting the paper's efficiency
columns for every sampler strategy.

Every sampler is a ``DecodeStrategy`` declaration over the unified
block-decode engine (``repro.core.block_loop``); the final row runs the
CDLM strategy under the **continuous block-level batching** scheduler
(``repro.serving.ContinuousEngine``): a persistent decode batch where
finished lanes are evicted at block boundaries, their cache rows reset,
and queued requests admitted mid-flight. Its API mirrors ``Engine``
(``warmup()`` / ``generate(requests)``) with two extra per-request knobs —
``Request.max_tokens`` (generation cap, rounded up to a block) and
``Request.arrival_s`` (trace replay offset) — and true per-request
latency/queueing in each ``Response``.

    PYTHONPATH=src python examples/serve_blockwise.py [--sampler cdlm]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import time

import numpy as np

from benchmarks import common
from repro.configs.base import ServeConfig
from repro.data.synthetic import verify
from repro.serving import Request, efficiency_report, make_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sampler", default="all",
                    choices=["all", "vanilla", "fast_dllm", "dual_cache",
                             "interval_cache", "cdlm"])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    print("loading/training assets (cached under experiments/bench_assets)...")
    teacher = common.get_teacher()
    student = common.get_student(teacher)
    ev = common.corpus().eval_batch(args.requests)
    reqs = [Request(prompt=p, id=i) for i, p in enumerate(ev["prompt"])]

    samplers = (["vanilla", "fast_dllm", "dual_cache", "interval_cache",
                 "cdlm"] if args.sampler == "all" else [args.sampler])
    rows = [(name, "static") for name in samplers]
    if args.sampler in ("all", "cdlm"):
        rows.append(("cdlm", "continuous"))

    # TPS is total served tokens / wall-clock for the whole request set, so
    # the column is comparable across schedulers (per-request latency_s
    # means different things: compute share for static, arrival->completion
    # including queueing for continuous).
    print(f"\n{'sampler':16s} {'sched':11s} {'TPS':>8} {'lat(ms)':>9} "
          f"{'steps':>7} {'genlen':>7} {'score':>6}")
    for name, sched in rows:
        params = student if name == "cdlm" else teacher
        serve = ServeConfig(max_batch=args.batch,
                            block_size=common.CDLM_CFG.block_size,
                            gen_length=common.TASK.gen_len, sampler=name,
                            scheduler=sched)
        eng = make_engine(params, common.CFG, serve,
                          prompt_len=common.TASK.prompt_len)
        eng.warmup()
        t0 = time.perf_counter()
        resp = eng.generate(reqs)
        wall = time.perf_counter() - t0
        rep = efficiency_report(resp)
        tps = sum(r.gen_length for r in resp) / wall if wall else 0.0
        ok = np.mean([verify(ev["prompt"][r.id], r.tokens, common.TASK)
                      for r in resp])
        print(f"{name:16s} {sched:11s} {tps:>8.0f} "
              f"{rep['latency_s']*1e3:>9.2f} {rep['steps']:>7.1f} "
              f"{rep['gen_length']:>7.1f} {ok:>6.2f}")


if __name__ == "__main__":
    main()
