"""Configurable end-to-end CDLM training driver.

Runs the full paper pipeline (teacher Eq.-6 SFT -> Alg.-1 trajectory
collection -> Alg.-2 consistency distillation, optionally LoRA) on any
assigned architecture's REDUCED variant and either synthetic task.

    PYTHONPATH=src python examples/train_cdlm.py --arch qwen2-0.5b \
        --task add --teacher-steps 800 --student-steps 300 --lora
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs.base import CDLMConfig, TrainConfig
from repro.configs.registry import ASSIGNED_IDS, get_config
from repro.core import masks
from repro.core.sampler import SamplerSpec, cdlm, vanilla_blockwise
from repro.data import Corpus, TaskSpec
from repro.data.synthetic import score
from repro.training import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ASSIGNED_IDS)
    ap.add_argument("--task", default="sort", choices=["sort", "add"])
    ap.add_argument("--teacher-steps", type=int, default=700)
    ap.add_argument("--student-steps", type=int, default=300)
    ap.add_argument("--block-size", type=int, default=5)
    ap.add_argument("--lora", action="store_true")
    ap.add_argument("--save", default=None, help="checkpoint prefix")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(dtype="float32")
    if cfg.family == "ssm":
        print(f"{args.arch} is attention-free: CDLM is inapplicable "
              "(DESIGN.md §5); training the AR path instead.")
    task = TaskSpec(args.task, vocab_size=cfg.vocab_size, prompt_len=15,
                    gen_len=10, sort_k=8, sort_range=24, add_digits=4)
    cdlm_cfg = CDLMConfig(block_size=args.block_size, gen_length=10,
                          prompt_length=15, temperatures=(0.0,))
    corpus = Corpus(task, 768, seed=0)
    tcfg = TrainConfig(learning_rate=2e-3, steps=args.teacher_steps,
                       batch_size=32, remat=False, use_lora=args.lora)

    if cfg.family == "ssm":
        model = trainer.train_ar(cfg, corpus, tcfg)
        if args.save:
            save(model, args.save + "_ar.npz")
        return

    # hybrid backbones (jamba) train the student-only block-diffusion form
    teacher_mode = (masks.BLOCK_CAUSAL if cfg.family == "hybrid"
                    else masks.BIDIRECTIONAL)
    print(f"== teacher ({teacher_mode}) ==")
    teacher = trainer.train_teacher(cfg, corpus, tcfg, mode=teacher_mode,
                                    block_size=args.block_size)
    print("== trajectories (Alg. 1) ==")
    ds = trainer.collect_dataset(teacher, cfg, cdlm_cfg, corpus,
                                 n_examples=128, batch=32)
    print(f"== student (Alg. 2{' + LoRA' if args.lora else ''}) ==")
    scfg = dataclasses.replace(tcfg, steps=args.student_steps,
                               learning_rate=5e-4)
    student = trainer.train_student(teacher, ds, cfg, cdlm_cfg, scfg)

    ev = corpus.eval_batch(32)
    prompts = jnp.asarray(ev["prompt"])
    spec = SamplerSpec(prompt_len=15, gen_len=10, block_size=args.block_size,
                       conf_threshold=0.9)
    rt = jax.jit(lambda p, x: vanilla_blockwise(p, x, cfg=cfg, spec=spec))(
        teacher, prompts)
    rs = jax.jit(lambda p, x: cdlm(p, x, cfg=cfg, spec=spec))(
        student, prompts)
    print(f"teacher: score={score(ev['prompt'], np.asarray(rt.tokens), 15, task):.2f} "
          f"steps={float(rt.steps.mean()):.1f}")
    print(f"student: score={score(ev['prompt'], np.asarray(rs.tokens), 15, task):.2f} "
          f"steps={float(rs.steps.mean()):.1f}")
    if args.save:
        save(teacher, args.save + "_teacher.npz")
        save(student, args.save + "_student.npz")
        print(f"saved to {args.save}_{{teacher,student}}.npz")


if __name__ == "__main__":
    main()
