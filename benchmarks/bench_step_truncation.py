"""Table 4 analog: naively truncating the teacher's step budget (threshold-0
parallel finalization => ~1 step/block) vs CDLM at a comparable budget."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import common
from repro.core.sampler import cdlm, fast_dllm_parallel, vanilla_blockwise


def run(csv_rows=None):
    teacher = common.get_teacher()
    student = common.get_student(teacher)

    full = common.eval_sampler(teacher, vanilla_blockwise)
    trunc = common.eval_sampler(teacher, fast_dllm_parallel,
                                conf_threshold=0.0)
    ours = common.eval_sampler(student, cdlm, conf_threshold=0.9)

    print("\n== Table 4 analog: step truncation ==")
    print(f"{'method':28s} {'steps':>7} {'lat(ms)':>9} {'score':>6}")
    for name, r in [("teacher full budget", full),
                    ("teacher truncated (naive)", trunc),
                    ("CDLM student", ours)]:
        print(f"{name:28s} {r['steps']:>7.1f} {r['latency_s']*1e3:>9.2f} "
              f"{r['score']:>6.2f}")
        if csv_rows is not None:
            csv_rows.append((f"step_truncation/{name.replace(' ', '_')}",
                             r["latency_s"] * 1e6,
                             f"score={r['score']:.2f};steps={r['steps']:.1f}"))
    assert trunc["score"] <= full["score"], "truncation should hurt"
    return csv_rows


if __name__ == "__main__":
    run()
