"""Table 3 analog: loss-weight composition ablation.

Trains short CDLM students under different (w_distill, w_cons, w_dlm) and
reports score + refinement steps. The paper's headline findings checked
here: consistency-only collapses; distill+consistency beats distill-only on
steps at comparable quality."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import common
from repro.core.sampler import cdlm

VARIANTS = [
    ("distill-only", (1.0, 0.0, 0.01)),
    ("consistency-only", (0.0, 1.0, 0.01)),
    ("distill+cons", (1.0, 0.5, 0.01)),
    ("no-dlm", (1.0, 0.5, 0.0)),
]


def run(csv_rows=None, steps=250):
    teacher = common.get_teacher()
    dataset = common.get_dataset(teacher)
    print("\n== Table 3 analog: loss-weight ablation ==")
    print(f"{'variant':18s} {'(wd,wc,wm)':>16} {'score':>6} {'steps':>7}")
    results = {}
    for name, w in VARIANTS:
        student = common.get_student(
            teacher, dataset, weights=w, steps=steps,
            cache_name=f"student_w{w[0]}_{w[1]}_{w[2]}.npz")
        r = common.eval_sampler(student, cdlm, conf_threshold=0.9)
        results[name] = r
        print(f"{name:18s} {str(w):>16} {r['score']:>6.2f} "
              f"{r['steps']:>7.1f}")
        if csv_rows is not None:
            csv_rows.append((f"loss_weights/{name}", r["latency_s"] * 1e6,
                             f"score={r['score']:.2f};steps={r['steps']:.1f}"))
    # paper row 2: consistency-only collapses
    assert results["consistency-only"]["score"] <= \
        results["distill+cons"]["score"], "consistency-only should not win"
    return csv_rows


if __name__ == "__main__":
    run()
