"""Tables 1–2 analog: TPS / Latency / Total Steps / Gen Length / Score for
the naive DLM, every acceleration baseline, CDLM, and the AR reference —
on the synthetic sort task at toy scale."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import common
from repro.configs.base import TrainConfig
from repro.core.sampler import SAMPLERS
from repro.training import trainer


def run(csv_rows=None):
    teacher = common.get_teacher()
    student = common.get_student(teacher)

    methods = [
        ("vanilla-DLM (teacher)", "vanilla", teacher, {}),
        ("dLLM-Cache (interval)", "interval_cache", teacher, {}),
        ("Fast-dLLM (Par.)", "fast_dllm", teacher, {}),
        ("Fast-dLLM (Par.+D.C.)", "dual_cache", teacher, {}),
        ("CDLM (ours)", "cdlm", student, {"early_stop": True}),
    ]
    # AR reference (Fig. 3): same-size model trained autoregressively
    ar_path = common._path("ar_baseline.npz")
    import jax
    from repro.checkpoint import restore, save
    from repro.models import init_model
    template = init_model(jax.random.PRNGKey(0), common.CFG)
    if os.path.exists(ar_path):
        ar_params = restore(template, ar_path)
    else:
        tcfg = TrainConfig(learning_rate=2e-3, steps=common.TEACHER_STEPS,
                           batch_size=64, remat=False)
        ar_params = trainer.train_ar(common.CFG, common.corpus(), tcfg,
                                     verbose=False)
        save(ar_params, ar_path)
    methods.append(("AR baseline", "ar", ar_params, {"early_stop": True}))

    base = None
    print(f"\n== Tables 1-2 analog (sort task, {common.CFG.n_layers}L "
          f"d{common.CFG.d_model}) ==")
    print(f"{'method':24s} {'TPS':>8} {'lat(ms)':>9} {'steps':>7} "
          f"{'genlen':>7} {'score':>6}")
    for name, key, params, kw in methods:
        r = common.eval_sampler(params, SAMPLERS[key], **kw)
        if base is None:
            base = r
        sp_t = r["tps"] / base["tps"] if base["tps"] else 0
        sp_l = base["latency_s"] / r["latency_s"] if r["latency_s"] else 0
        print(f"{name:24s} {r['tps']:>8.0f} {r['latency_s']*1e3:>9.2f} "
              f"{r['steps']:>7.1f} {r['gen_len']:>7.1f} {r['score']:>6.2f}"
              f"   (x{sp_t:.1f} TPS, x{sp_l:.1f} lat)")
        if csv_rows is not None:
            csv_rows.append((f"main_results/{key}",
                             r["latency_s"] * 1e6,
                             f"score={r['score']:.2f};steps={r['steps']:.1f};"
                             f"tps={r['tps']:.0f}"))
    return csv_rows


if __name__ == "__main__":
    run()
