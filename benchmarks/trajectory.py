"""Per-PR benchmark trajectory: schema'd run records in a JSONL ratchet.

Every CI bench run appends one line to ``BENCH_trajectory.jsonl``:

    {"schema": 1, "ts": ..., "sha": ..., "backend": ..., "smoke": ...,
     "metrics": {<tracked name>: <float>, ...},
     "records": [{op, shape, backend, metric, value, config}, ...]}

``metrics`` are the *tracked* scalars the regression gate compares —
ratios and counts chosen to be stable across machines (absolute
microseconds are not comparable between CI hosts and are carried only in
``records`` for inspection). The gate (``python -m benchmarks.trajectory
gate``) compares a candidate run against the last recorded line and fails
on a regression beyond each metric's tolerance: relative (default 10%)
for ratio metrics, absolute slack for counts.

    # build a candidate from bench --json artifacts and gate it
    python -m benchmarks.trajectory gate \
        --kernels BENCH_kernels.json --serving BENCH_serving.json
    # record it (CI appends only after the gate passes)
    python -m benchmarks.trajectory append \
        --kernels BENCH_kernels.json --serving BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

SCHEMA_VERSION = 1
DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_trajectory.jsonl")

# The ratchet: direction says which way is good; rel_tol is the allowed
# fractional regression vs the last recorded run (the >10% CI gate),
# abs_tol an absolute slack for small counts. Tolerances are per-metric
# because smoke-scale traces are noisier for some ratios than others.
TRACKED: Dict[str, Dict[str, Any]] = {
    # fused-select speedup over the dense (T, V) selection baseline, per
    # vocab bucket — the headline kernel number (>= 1.0 means fused wins)
    "select_speedup_V32768": {"direction": "higher", "rel_tol": 0.10},
    "select_speedup_V131072": {"direction": "higher", "rel_tol": 0.10},
    # continuous vs static scheduling throughput on the Poisson trace
    # (host-pacing sensitive at smoke scale -> wider tolerance)
    "continuous_static_speedup": {"direction": "higher", "rel_tol": 0.25},
    # paged vs dense engine throughput at the same KV byte budget (the
    # noisiest smoke ratio: a 10-request trace on a shared CI host)
    "paged_dense_tps_ratio": {"direction": "higher", "rel_tol": 0.50},
    # peak concurrent lanes per byte — structural, near-deterministic
    "paged_concurrency_gain": {"direction": "higher", "rel_tol": 0.10},
    # paged scheduling quality: boundaries where a live lane sat
    # page-starved (count; absolute slack, not a ratio)
    "paged_stall_rounds": {"direction": "lower", "abs_tol": 2.0},
}


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def metrics_from(kernels: Optional[dict], serving: Optional[dict]
                 ) -> Tuple[Dict[str, float], List[dict]]:
    """Extract (tracked metrics, shared-schema records) from the two bench
    ``--json`` artifacts. Either may be None — the gate skips metrics that
    are absent on one side of the comparison."""
    metrics: Dict[str, float] = {}
    records: List[dict] = []
    if kernels:
        for bucket, row in (kernels.get("select") or {}).items():
            if "speedup" in row:
                metrics[f"select_speedup_{bucket}"] = float(row["speedup"])
        records.extend(kernels.get("records") or [])
    if serving:
        sched = serving.get("schedulers") or {}
        if "speedup" in sched:
            metrics["continuous_static_speedup"] = float(sched["speedup"])
        lay = serving.get("layouts") or {}
        if "paged" in lay and "dense" in lay:
            dtps = float(lay["dense"].get("tps") or 0.0)
            if dtps > 0:
                metrics["paged_dense_tps_ratio"] = \
                    float(lay["paged"]["tps"]) / dtps
            pool = lay["paged"].get("pool") or {}
            if "stall_rounds" in pool:
                metrics["paged_stall_rounds"] = float(pool["stall_rounds"])
        if "concurrency_gain" in lay:
            metrics["paged_concurrency_gain"] = float(lay["concurrency_gain"])
        records.extend(serving.get("records") or [])
    return metrics, records


def build_run(kernels_path: Optional[str], serving_path: Optional[str]
              ) -> dict:
    """One trajectory line from the bench artifacts on disk."""
    def _load(p):
        if not p:
            return None
        with open(p) as f:
            return json.load(f)

    kernels, serving = _load(kernels_path), _load(serving_path)
    metrics, records = metrics_from(kernels, serving)
    smoke = bool((kernels or {}).get("smoke") or (serving or {}).get("smoke"))
    backend = next((r["backend"] for r in records if r.get("backend")),
                   "unknown")
    return {"schema": SCHEMA_VERSION,
            "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "sha": _git_sha(), "backend": backend, "smoke": smoke,
            "metrics": metrics, "records": records}


def load_runs(path: str) -> List[dict]:
    if not os.path.exists(path):
        return []
    runs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                runs.append(json.loads(line))
    return runs


def append_run(path: str, run: dict) -> None:
    with open(path, "a") as f:
        f.write(json.dumps(run, sort_keys=True) + "\n")


def gate(candidate: dict, previous: Optional[dict],
         tracked: Optional[Dict[str, Dict[str, Any]]] = None) -> List[str]:
    """Regression failures of ``candidate`` vs ``previous`` (the last
    recorded run). No previous run, or a metric missing on either side,
    is a clean pass for that metric — the ratchet only tightens once a
    number has been recorded."""
    if previous is None:
        return []
    tracked = TRACKED if tracked is None else tracked
    fails = []
    prev_m = previous.get("metrics") or {}
    cand_m = candidate.get("metrics") or {}
    for name, spec in tracked.items():
        if name not in prev_m or name not in cand_m:
            continue
        prev, cand = float(prev_m[name]), float(cand_m[name])
        higher = spec.get("direction", "higher") == "higher"
        if "abs_tol" in spec:
            limit = prev - spec["abs_tol"] if higher else prev + spec["abs_tol"]
            bad = cand < limit if higher else cand > limit
        else:
            tol = spec.get("rel_tol", 0.10)
            limit = prev * (1 - tol) if higher else prev * (1 + tol)
            bad = cand < limit if higher else cand > limit
        if bad:
            fails.append(
                f"{name}: {cand:.4g} vs last {prev:.4g} "
                f"(limit {'>=' if higher else '<='} {limit:.4g})")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("append", "gate", "show"):
        sp = sub.add_parser(name)
        sp.add_argument("--trajectory", default=DEFAULT_PATH, metavar="PATH")
        if name != "show":
            sp.add_argument("--kernels", default=None, metavar="JSON")
            sp.add_argument("--serving", default=None, metavar="JSON")
    args = ap.parse_args(argv)

    if args.cmd == "show":
        for run in load_runs(args.trajectory):
            m = ", ".join(f"{k}={v:.3g}"
                          for k, v in sorted(run["metrics"].items()))
            print(f"{run['ts']} {run['sha']:>9} smoke={run['smoke']} {m}")
        return 0

    if not args.kernels and not args.serving:
        ap.error(f"{args.cmd} needs --kernels and/or --serving artifacts")
    run = build_run(args.kernels, args.serving)

    if args.cmd == "append":
        append_run(args.trajectory, run)
        print(f"appended run {run['sha']} "
              f"({len(run['metrics'])} tracked metrics, "
              f"{len(run['records'])} records) -> {args.trajectory}")
        return 0

    runs = load_runs(args.trajectory)
    previous = runs[-1] if runs else None
    fails = gate(run, previous)
    if fails:
        print("bench trajectory REGRESSION vs last recorded run:")
        for f in fails:
            print(f"  {f}")
        return 1
    compared = (sorted(set(run['metrics']) & set(TRACKED)
                       & set((previous or {}).get('metrics', {})))
                if previous else [])
    print("bench trajectory gate: OK "
          f"({len(compared)} metrics vs {previous['sha'] if previous else '—'}"
          f"{': ' + ', '.join(compared) if compared else ' (first run)'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
