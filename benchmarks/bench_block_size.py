"""Fig. 8 analog: inference-time block-size sweep on a student trained with
a fixed block size — throughput rises with B; accuracy peaks at the
training block size (train-inference match)."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import common
from repro.core.sampler import cdlm


def run(csv_rows=None):
    student = common.get_student()
    train_B = common.CDLM_CFG.block_size
    print(f"\n== Fig. 8 analog: inference block size (trained B={train_B}) ==")
    print(f"{'B':>4} {'TPS':>8} {'steps':>7} {'score':>6}")
    for B in (1, 2, 5, 10):
        if common.TASK.gen_len % B:
            continue
        r = common.eval_sampler(student, cdlm, block_size=B)
        mark = " <- train B" if B == train_B else ""
        print(f"{B:>4} {r['tps']:>8.0f} {r['steps']:>7.1f} "
              f"{r['score']:>6.2f}{mark}")
        if csv_rows is not None:
            csv_rows.append((f"block_size/B{B}", r["latency_s"] * 1e6,
                             f"score={r['score']:.2f};steps={r['steps']:.1f}"))
    return csv_rows


if __name__ == "__main__":
    run()
