"""Fig. 4 + App. B.4 reproduction: the analytic arithmetic-intensity model
with the paper's own configurations (LLaMA-3.1-8B AR / LLaDA-8B DLM on an
A100-SXM4-80GB). Pure analysis — runs exactly on CPU."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import A100, TPU_V5E
from repro.roofline.ai_model import (
    LLADA_8B,
    PAPER_TARGETS,
    attainable_tflops,
    blockwise_dlm_ai,
    paper_table,
)


def run(csv_rows=None):
    print("\n== Fig. 4 / App. B.4: arithmetic intensity (analytic) ==")
    print(f"A100 ridge point: {A100.ridge_ai:.1f} FLOP/B (paper: 153.0)  |  "
          f"TPU v5e ridge: {TPU_V5E.ridge_ai:.1f}")
    rows = paper_table()
    print(f"{'bs':>4} {'AR':>8} {'vanilla':>9} {'B=4':>8} {'B=16':>8} "
          f"{'B=32':>8}   (AI, FLOP/byte)")
    for r in rows:
        print(f"{r['batch']:>4} {r['ar']:>8.1f} {r['vanilla']:>9.1f} "
              f"{r['block4']:>8.1f} {r['block16']:>8.1f} {r['block32']:>8.1f}")

    print("\nvs paper targets (bs where given):")
    r1 = {r["batch"]: r for r in rows}
    checks = []
    for (kind, bs), want in sorted(PAPER_TARGETS.items()):
        got = r1[bs][kind]
        dev = (got - want) / want * 100
        checks.append(abs(dev))
        print(f"  {kind:8s} bs={bs:<4d} ours={got:7.1f}  paper={want:7.1f} "
              f" ({dev:+.0f}%)")
        if csv_rows is not None:
            csv_rows.append((f"ai_model/{kind}_bs{bs}", 0.0,
                             f"ai={got:.1f};paper={want:.1f}"))
    print(f"  max |deviation| = {max(checks):.0f}% "
          "(accounting differences documented in roofline/ai_model.py)")

    # qualitative structure asserts (the paper's §5.4 claims)
    assert r1[1]["ar"] < 2 < A100.ridge_ai, "AR must be memory-bound at bs=1"
    assert r1[1]["vanilla"] > A100.ridge_ai, "vanilla DLM compute-bound at bs=1"
    assert r1[1]["ar"] < r1[1]["block32"] < r1[1]["vanilla"]
    # ridge crossing: B=32 crosses by bs~8, B=16 by bs~16 (paper's numbers)
    assert r1[8]["block32"] > A100.ridge_ai
    assert r1[16]["block16"] > A100.ridge_ai
    # beyond the paper: decode AI once the fused unembed+select kernel
    # (repro.kernels.select) removes the (T, V) logits round-trip
    print("\nblock-wise (B=32) AI with fused unembed+select:")
    for bs in (1, 8, 32):
        dense = blockwise_dlm_ai(LLADA_8B, bs, 32)
        fused = blockwise_dlm_ai(LLADA_8B, bs, 32, fused_select=True)
        assert fused > dense, "fused select must strictly raise AI"
        print(f"  bs={bs:<4d} dense-lm_head={dense:7.1f}  "
              f"fused={fused:7.1f}  (x{fused / dense:.2f})")
        if csv_rows is not None:
            csv_rows.append((f"ai_model/block32_fused_bs{bs}", 0.0,
                             f"ai={fused:.1f};dense={dense:.1f}"))

    # roofline placement (App. B.4): attainable TFLOP/s
    print("\nattainable TFLOP/s on A100 (roofline):")
    for kind in ("ar", "vanilla", "block32"):
        print(f"  {kind:8s} bs=1: {attainable_tflops(r1[1][kind]):7.1f}"
              f"   bs=128: {attainable_tflops(r1[128][kind]):7.1f}"
              f"   (peak {A100.peak_flops/1e12:.1f})")
    return csv_rows


if __name__ == "__main__":
    run()
