"""Table 7 analog: token-confidence threshold sweep on the CDLM student —
speed must be monotone in tau; quality trades off at the aggressive end."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import common
from repro.core.sampler import cdlm


def run(csv_rows=None):
    student = common.get_student()
    print("\n== Table 7 analog: tau_conf sweep (CDLM student) ==")
    print(f"{'tau':>6} {'TPS':>8} {'lat(ms)':>9} {'steps':>7} {'score':>6}")
    rows = []
    for tau in (0.95, 0.9, 0.85, 0.5):
        r = common.eval_sampler(student, cdlm, conf_threshold=tau)
        rows.append((tau, r))
        print(f"{tau:>6.2f} {r['tps']:>8.0f} {r['latency_s']*1e3:>9.2f} "
              f"{r['steps']:>7.1f} {r['score']:>6.2f}")
        if csv_rows is not None:
            csv_rows.append((f"conf_threshold/tau{tau}",
                             r["latency_s"] * 1e6,
                             f"score={r['score']:.2f};steps={r['steps']:.1f}"))
    steps = [r["steps"] for _, r in rows]
    assert steps == sorted(steps, reverse=True), \
        f"steps must decrease as tau drops: {steps}"
    return csv_rows


if __name__ == "__main__":
    run()
