# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness driver — one module per paper table/figure:

  bench_arithmetic_intensity  Fig. 4 + App. B.4  (analytic, exact on CPU)
  bench_main_results          Tables 1-2         (toy-scale pipeline)
  bench_step_truncation       Table 4
  bench_conf_threshold        Table 7 / App. B.2
  bench_block_size            Fig. 8 / App. B.3
  bench_loss_weights          Table 3
  bench_kernels               kernel-layer microbench
  bench_serving               static vs continuous block-level batching

Run everything:   PYTHONPATH=src python -m benchmarks.run
One module:       PYTHONPATH=src python -m benchmarks.bench_main_results
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (
        bench_arithmetic_intensity,
        bench_block_size,
        bench_conf_threshold,
        bench_kernels,
        bench_loss_weights,
        bench_main_results,
        bench_serving,
        bench_step_truncation,
    )
    rows = []
    t0 = time.time()
    for mod in (bench_arithmetic_intensity, bench_kernels,
                bench_main_results, bench_step_truncation,
                bench_conf_threshold, bench_block_size, bench_loss_weights,
                bench_serving):
        print(f"\n##### {mod.__name__} ({time.time()-t0:.0f}s elapsed) #####")
        mod.run(csv_rows=rows)

    print("\n\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"\ntotal wall time: {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
