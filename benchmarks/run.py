# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness driver — one module per paper table/figure:

  arithmetic_intensity  Fig. 4 + App. B.4  (analytic, exact on CPU)
  main_results          Tables 1-2         (toy-scale pipeline)
  step_truncation       Table 4
  conf_threshold        Table 7 / App. B.2
  block_size            Fig. 8 / App. B.3
  loss_weights          Table 3
  kernels               kernel-layer microbench
  serving               static vs continuous block-level batching
  trajectory            per-PR bench ratchet (append/gate/show)

Run everything:   PYTHONPATH=src python -m benchmarks.run
One benchmark:    PYTHONPATH=src python -m benchmarks.run kernels [args...]
                  (arguments after the name go to that benchmark's own
                  CLI, e.g. ``run.py serving --smoke --json out.json``)
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# subcommand -> module name under benchmarks/; every module exposes
# ``run(csv_rows=...)`` for the run-everything sweep and ``main(argv)``
# for its own CLI (trajectory has main() only — it is not a timed bench)
MODULES = {
    "arithmetic_intensity": "bench_arithmetic_intensity",
    "kernels": "bench_kernels",
    "main_results": "bench_main_results",
    "step_truncation": "bench_step_truncation",
    "conf_threshold": "bench_conf_threshold",
    "block_size": "bench_block_size",
    "loss_weights": "bench_loss_weights",
    "serving": "bench_serving",
    "trajectory": "trajectory",
}


def _import(name):
    import importlib
    return importlib.import_module(f"benchmarks.{MODULES[name]}")


def run_all() -> None:
    rows = []
    t0 = time.time()
    for name in ("arithmetic_intensity", "kernels", "main_results",
                 "step_truncation", "conf_threshold", "block_size",
                 "loss_weights", "serving"):
        mod = _import(name)
        print(f"\n##### {mod.__name__} ({time.time()-t0:.0f}s elapsed) #####")
        mod.run(csv_rows=rows)

    print("\n\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"\ntotal wall time: {time.time()-t0:.0f}s")


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or argv[0] in ("all",):
        run_all()
        return
    if argv[0] in ("-h", "--help"):
        print(__doc__)
        print("subcommands:", ", ".join(sorted(MODULES)), "| all")
        return
    name = argv[0]
    if name not in MODULES:
        raise SystemExit(
            f"unknown benchmark {name!r} — expected one of "
            f"{sorted(MODULES)} or 'all'")
    mod = _import(name)
    if hasattr(mod, "main"):
        ret = mod.main(argv[1:])
        if ret:
            raise SystemExit(ret)
    else:
        # table benches without their own CLI: plain run()
        if argv[1:]:
            raise SystemExit(f"benchmark {name!r} takes no arguments")
        mod.run()


if __name__ == "__main__":
    main()
