"""Serving-scheduler benchmark: static fixed-shape batching vs continuous
block-level batching on a Poisson arrival trace with mixed generation
lengths (per-request ``max_tokens`` caps).

Static batching pads requests into fixed chunks and runs each chunk to
completion: a lane capped at one block still rides along for the full
block grid, and a chunk cannot launch until its last request has arrived.
The continuous engine evicts finished lanes at every block boundary and
admits queued requests into the freed cache rows mid-flight, so short
requests release their lanes early and the decode batch stays full.

    PYTHONPATH=src python -m benchmarks.bench_serving
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks import common
from repro.configs.base import ServeConfig


def _run_static_trace(eng, reqs, max_batch):
    """Replay the trace through the static engine: chunks form in arrival
    order and launch once every member has arrived."""
    by_id = {r.id: r for r in reqs}
    lat = {}
    out = []
    t0 = time.perf_counter()
    for i in range(0, len(reqs), max_batch):
        chunk = reqs[i:i + max_batch]
        ready_at = max(r.arrival_s for r in chunk)
        now = time.perf_counter() - t0
        if ready_at > now:
            time.sleep(ready_at - now)
        rs = eng.generate(chunk)
        done = time.perf_counter() - t0
        for r in rs:
            lat[r.id] = done - by_id[r.id].arrival_s
        out.extend(rs)
    return out, lat, time.perf_counter() - t0


def _report(name, resp, lat_by_id, makespan):
    toks = sum(r.gen_length for r in resp)
    lats = np.asarray(sorted(lat_by_id.values()))
    tps = toks / makespan if makespan > 0 else float("inf")
    print(f"{name:12s} {tps:>9.0f} {makespan*1e3:>10.1f} "
          f"{np.median(lats)*1e3:>9.1f} {lats[int(0.95*(len(lats)-1))]*1e3:>9.1f} "
          f"{toks:>7d}")
    return tps


def run(csv_rows=None, n_requests=96, max_batch=4, rate_hz=1000.0):
    from repro.serving import ContinuousEngine, Engine

    student = common.get_student()
    reqs = common.poisson_trace(n=n_requests, rate_hz=rate_hz, seed=0)
    kw = dict(block_size=common.CDLM_CFG.block_size,
              gen_length=common.TASK.gen_len, sampler="cdlm",
              conf_threshold=0.9, max_batch=max_batch)

    static_eng = Engine(student, common.CFG,
                        ServeConfig(scheduler="static", **kw),
                        prompt_len=common.TASK.prompt_len)
    cont_eng = ContinuousEngine(student, common.CFG,
                                ServeConfig(scheduler="continuous", **kw),
                                prompt_len=common.TASK.prompt_len)
    static_eng.warmup()
    cont_eng.warmup()

    print(f"\n== serving schedulers ({n_requests} reqs, Poisson "
          f"{rate_hz:.0f}/s, batch {max_batch}, mixed max_tokens) ==")
    print(f"{'scheduler':12s} {'tok/s':>9} {'makespan':>10} {'p50 lat':>9} "
          f"{'p95 lat':>9} {'tokens':>7}")

    s_resp, s_lat, s_make = _run_static_trace(static_eng, reqs, max_batch)
    s_tps = _report("static", s_resp, s_lat, s_make)

    t0 = time.perf_counter()
    c_resp = cont_eng.generate(reqs)
    c_make = time.perf_counter() - t0
    c_lat = {r.id: r.latency_s for r in c_resp}
    c_tps = _report("continuous", c_resp, c_lat, c_make)

    assert len(c_resp) == len(s_resp) == n_requests
    speedup = c_tps / s_tps if s_tps else float("inf")
    verdict = "OK" if c_tps >= s_tps else "REGRESSION"
    print(f"continuous/static throughput: x{speedup:.2f}  [{verdict}]")

    if csv_rows is not None:
        csv_rows.append(("serving/static_tps", s_make * 1e6 / n_requests,
                         f"{s_tps:.0f}"))
        csv_rows.append(("serving/continuous_tps", c_make * 1e6 / n_requests,
                         f"{c_tps:.0f}"))
        csv_rows.append(("serving/speedup", 0.0, f"{speedup:.2f}"))
    return speedup


if __name__ == "__main__":
    run()
