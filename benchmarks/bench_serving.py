"""Serving benchmarks: (1) static fixed-shape batching vs continuous
block-level batching on a Poisson arrival trace with mixed generation
lengths, and (2) dense vs block-paged KV layouts at a fixed page-pool
memory budget.

Static batching pads requests into fixed chunks and runs each chunk to
completion: a lane capped at one block still rides along for the full
block grid, and a chunk cannot launch until its last request has arrived.
The continuous engine evicts finished lanes at every block boundary and
admits queued requests into the freed cache rows mid-flight, so short
requests release their lanes early and the decode batch stays full.

The layout face-off fixes the KV byte budget: the dense engine gets
``budget_pages // pages_per_canvas`` lanes (every lane preallocates the
whole canvas), while the paged engine gets the same budget as a shared
page pool and more lanes — short requests only consume the pages they
commit, so the pool sustains more concurrent decodes per HBM byte.

    PYTHONPATH=src python -m benchmarks.bench_serving
    PYTHONPATH=src python -m benchmarks.bench_serving --cache-layout paged
    PYTHONPATH=src python -m benchmarks.bench_serving --smoke \
        --json BENCH_serving.json
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks import common
from repro.configs.base import ServeConfig


def _run_static_trace(eng, reqs, max_batch):
    """Replay the trace through the static engine: chunks form in arrival
    order and launch once every member has arrived."""
    by_id = {r.id: r for r in reqs}
    lat = {}
    out = []
    t0 = time.perf_counter()
    for i in range(0, len(reqs), max_batch):
        chunk = reqs[i:i + max_batch]
        ready_at = max(r.arrival_s for r in chunk)
        now = time.perf_counter() - t0
        if ready_at > now:
            time.sleep(ready_at - now)
        rs = eng.generate(chunk)
        done = time.perf_counter() - t0
        for r in rs:
            lat[r.id] = done - by_id[r.id].arrival_s
        out.extend(rs)
    return out, lat, time.perf_counter() - t0


def _report(name, resp, lat_by_id, makespan):
    toks = sum(r.gen_length for r in resp)
    lats = np.asarray(sorted(lat_by_id.values()))
    tps = toks / makespan if makespan > 0 else float("inf")
    print(f"{name:12s} {tps:>9.0f} {makespan*1e3:>10.1f} "
          f"{np.median(lats)*1e3:>9.1f} {lats[int(0.95*(len(lats)-1))]*1e3:>9.1f} "
          f"{toks:>7d}")
    return tps


def _kv_page_bytes():
    """KV bytes of one pool page (all attention slots, K+V)."""
    import jax

    from repro.core import cache as C
    T = common.TASK.prompt_len + common.TASK.gen_len
    paged = jax.eval_shape(lambda: C.init_paged_cache(
        common.CFG, 1, T, n_pages=1,
        page_size=common.CDLM_CFG.block_size, dtype=common.CFG.dtype))
    return sum(leaf.size * leaf.dtype.itemsize
               for slot in paged.slots for k, leaf in slot.items()
               if k in ("k", "v"))


def run_schedulers(params, csv_rows=None, results=None, n_requests=96,
                   max_batch=4, rate_hz=1000.0, sampled_frac=0.0):
    """Static vs continuous scheduling (dense layout). ``sampled_frac``
    mixes per-request sampled lanes (temperature 0.7) into the trace —
    both schedulers serve them through the per-lane params path."""
    from repro.serving import ContinuousEngine, Engine

    reqs = common.poisson_trace(n=n_requests, rate_hz=rate_hz, seed=0,
                                sampled_frac=sampled_frac)
    kw = dict(block_size=common.CDLM_CFG.block_size,
              gen_length=common.TASK.gen_len, sampler="cdlm",
              conf_threshold=0.9, max_batch=max_batch)

    static_eng = Engine(params, common.CFG,
                        ServeConfig(scheduler="static", **kw),
                        prompt_len=common.TASK.prompt_len)
    cont_eng = ContinuousEngine(params, common.CFG,
                                ServeConfig(scheduler="continuous", **kw),
                                prompt_len=common.TASK.prompt_len)
    # sampled traces hit the per-lane jit variants: precompile them so
    # the timed region measures scheduling, not one-off compiles
    static_eng.warmup(per_request=sampled_frac > 0)
    cont_eng.warmup(per_request=sampled_frac > 0)

    mix = (f", {sampled_frac:.0%} sampled lanes" if sampled_frac else "")
    print(f"\n== serving schedulers ({n_requests} reqs, Poisson "
          f"{rate_hz:.0f}/s, batch {max_batch}, mixed max_tokens{mix}) ==")
    print(f"{'scheduler':12s} {'tok/s':>9} {'makespan':>10} {'p50 lat':>9} "
          f"{'p95 lat':>9} {'tokens':>7}")

    s_resp, s_lat, s_make = _run_static_trace(static_eng, reqs, max_batch)
    s_tps = _report("static", s_resp, s_lat, s_make)

    t0 = time.perf_counter()
    c_resp = cont_eng.generate(reqs)
    c_make = time.perf_counter() - t0
    c_lat = {r.id: r.latency_s for r in c_resp}
    c_tps = _report("continuous", c_resp, c_lat, c_make)

    assert len(c_resp) == len(s_resp) == n_requests
    speedup = c_tps / s_tps if s_tps else float("inf")
    verdict = "OK" if c_tps >= s_tps else "REGRESSION"
    print(f"continuous/static throughput: x{speedup:.2f}  [{verdict}]")

    if csv_rows is not None:
        csv_rows.append(("serving/static_tps", s_make * 1e6 / n_requests,
                         f"{s_tps:.0f}"))
        csv_rows.append(("serving/continuous_tps", c_make * 1e6 / n_requests,
                         f"{c_tps:.0f}"))
        csv_rows.append(("serving/speedup", 0.0, f"{speedup:.2f}"))
    if results is not None:
        results["schedulers"] = {
            "n_requests": n_requests, "max_batch": max_batch,
            "static_tps": s_tps, "continuous_tps": c_tps,
            "speedup": speedup,
        }
        shape = {"n_requests": n_requests, "max_batch": max_batch}
        results.setdefault("records", []).extend([
            common.record("serving_sched", shape, "tok_per_s", s_tps,
                          config={"scheduler": "static"}),
            common.record("serving_sched", shape, "tok_per_s", c_tps,
                          config={"scheduler": "continuous"}),
            common.record("serving_sched", shape,
                          "continuous_static_speedup", speedup),
        ])
    return speedup


def run_layouts(params, csv_rows=None, results=None, n_requests=64,
                rate_hz=1000.0, budget_pages=12, paged_lanes=None):
    """Dense vs paged KV layout at the same page-pool memory budget.

    The dense engine's lane count is what the budget can preallocate
    (whole canvases); the paged engine shares the identical budget as a
    pool and admits by free pages, so mixed-length traffic packs more
    concurrent lanes into the same bytes.
    """
    from repro.serving import ContinuousEngine

    B = common.CDLM_CFG.block_size
    P, G = common.TASK.prompt_len, common.TASK.gen_len
    n_tables = -(-(P + G) // B)
    dense_lanes = max(1, budget_pages // n_tables)
    paged_lanes = paged_lanes or 2 * dense_lanes
    page_mb = _kv_page_bytes() / 1e6
    reqs = common.poisson_trace(n=n_requests, rate_hz=rate_hz, seed=1)

    kw = dict(block_size=B, gen_length=G, sampler="cdlm",
              conf_threshold=0.9, scheduler="continuous")
    dense_eng = ContinuousEngine(
        params, common.CFG,
        ServeConfig(max_batch=dense_lanes, **kw), prompt_len=P)
    paged_eng = ContinuousEngine(
        params, common.CFG,
        ServeConfig(max_batch=paged_lanes, cache_layout="paged",
                    page_pool_pages=budget_pages, **kw), prompt_len=P)
    dense_eng.warmup()
    paged_eng.warmup()

    print(f"\n== cache layouts at fixed budget ({budget_pages} pages = "
          f"{budget_pages * page_mb:.2f} MB KV; {n_requests} reqs, mixed "
          f"max_tokens; dense {dense_lanes} lanes, paged {paged_lanes} "
          "lanes) ==")
    print(f"{'layout':12s} {'tok/s':>9} {'makespan':>10} {'peak lanes':>10} "
          f"{'avg lanes':>10} {'pool peak':>9}")

    rows = {}
    for name, eng in (("dense", dense_eng), ("paged", paged_eng)):
        t0 = time.perf_counter()
        resp = eng.generate(reqs)
        make = time.perf_counter() - t0
        assert len(resp) == n_requests
        toks = sum(r.gen_length for r in resp)
        tps = toks / make if make > 0 else float("inf")
        conc = eng.concurrency_stats()
        pool = eng.page_pool_stats()
        occ = (f"{pool['peak_occupancy']:.0%}" if name == "paged" else "-")
        print(f"{name:12s} {tps:>9.0f} {make*1e3:>10.1f} "
              f"{conc['peak_lanes']:>10.0f} {conc['avg_lanes']:>10.2f} "
              f"{occ:>9}")
        rows[name] = {"tps": tps, "makespan_s": make, **conc,
                      **({"pool": pool} if name == "paged" else {})}

    gain = rows["paged"]["peak_lanes"] / max(rows["dense"]["peak_lanes"], 1)
    verdict = ("OK" if rows["paged"]["peak_lanes"]
               >= rows["dense"]["peak_lanes"] else "REGRESSION")
    print(f"paged/dense peak concurrency at fixed memory: x{gain:.2f}  "
          f"[{verdict}]")

    if csv_rows is not None:
        csv_rows.append(("serving/dense_peak_lanes", 0.0,
                         f"{rows['dense']['peak_lanes']:.0f}"))
        csv_rows.append(("serving/paged_peak_lanes", 0.0,
                         f"{rows['paged']['peak_lanes']:.0f}"))
        csv_rows.append(("serving/paged_concurrency_gain", 0.0,
                         f"{gain:.2f}"))
    if results is not None:
        results["layouts"] = {
            "budget_pages": budget_pages, "page_mb": page_mb,
            "dense_lanes": dense_lanes, "paged_lanes": paged_lanes,
            "concurrency_gain": gain, **rows,
        }
        shape = {"n_requests": n_requests, "budget_pages": budget_pages,
                 "dense_lanes": dense_lanes, "paged_lanes": paged_lanes}
        pool = rows["paged"]["pool"]
        results.setdefault("records", []).extend([
            common.record("serving_layout", shape, "tok_per_s",
                          rows["dense"]["tps"], config={"layout": "dense"}),
            common.record("serving_layout", shape, "tok_per_s",
                          rows["paged"]["tps"], config={"layout": "paged"}),
            common.record("serving_layout", shape, "paged_dense_tps_ratio",
                          rows["paged"]["tps"]
                          / max(rows["dense"]["tps"], 1e-9)),
            common.record("serving_layout", shape, "concurrency_gain", gain),
            common.record("serving_layout", shape, "stall_rounds",
                          pool["stall_rounds"], config={"layout": "paged"}),
            common.record("serving_layout", shape, "preemptions",
                          pool["preemptions"], config={"layout": "paged"}),
        ])
    return gain


def run(csv_rows=None, n_requests=96, max_batch=4, rate_hz=1000.0,
        results=None, params=None, layouts=True, budget_pages=12,
        sampled_frac=0.0):
    params = params if params is not None else common.get_student()
    speedup = run_schedulers(params, csv_rows=csv_rows, results=results,
                             n_requests=n_requests, max_batch=max_batch,
                             rate_hz=rate_hz, sampled_frac=sampled_frac)
    if layouts:
        run_layouts(params, csv_rows=csv_rows, results=results,
                    n_requests=max(8, n_requests * 2 // 3), rate_hz=rate_hz,
                    budget_pages=budget_pages)
    return speedup


def main(argv=None):
    ap = common.make_parser(
        description=__doc__,
        smoke_help="random-init params (no cached training assets) and a "
                   "short trace — CI-sized; scheduling and layout behavior "
                   "are model-quality independent")
    ap.add_argument("--cache-layout", default="both",
                    choices=["dense", "paged", "both"],
                    help="'dense' skips the layout face-off; 'paged'/'both' "
                         "run dense-vs-paged at a fixed page budget")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--budget-pages", type=int, default=12)
    ap.add_argument("--sampled-frac", type=float, default=0.0,
                    help="share of trace requests carrying per-request "
                         "SamplingParams (temperature 0.7, own seed) — "
                         "exercises mixed greedy/sampled batches")
    args = ap.parse_args(argv)

    if args.smoke:
        import jax

        from repro.models import init_model
        params = init_model(jax.random.PRNGKey(0), common.CFG)
        n_requests = args.requests or 16
    else:
        params = common.get_student()
        n_requests = args.requests or 96

    results = {"smoke": args.smoke, "n_requests": n_requests,
               "sampled_frac": args.sampled_frac, "records": []}
    run(results=results, params=params, n_requests=n_requests,
        layouts=args.cache_layout in ("paged", "both"),
        budget_pages=args.budget_pages, sampled_frac=args.sampled_frac)
    common.write_results(args.json, results)


if __name__ == "__main__":
    main()
