"""HTTP serving smoke: boot the stdlib frontend on a tiny random-init
CDLM engine, run one streamed and one non-streamed completion through
``urllib``, and assert both are token-identical to ``Engine.generate``
on an identical reference engine.

    PYTHONPATH=src python -m benchmarks.serve_smoke

Exercises, end to end: ``add_request``/``step()`` under the driver
thread, SSE block streaming (chunks must reassemble to the exact batch
decode), ``/healthz`` and ``/metrics``. Used by the CI ``serve-smoke``
job (``make serve-smoke``).
"""
from __future__ import annotations

import json
import os
import sys
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ServeConfig
from repro.configs.registry import get_config
from repro.models import init_model
from repro.serving import Request, make_engine
from repro.serving.server import serve_http

P, G, B = 8, 16, 4
CFG = get_config("qwen2-0.5b").reduced(dtype="float32")
SERVE = ServeConfig(max_batch=2, block_size=B, gen_length=G, sampler="cdlm",
                    conf_threshold=0.5, scheduler="continuous")


def _post(base, body):
    req = urllib.request.Request(
        f"{base}/v1/completions", data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    return urllib.request.urlopen(req, timeout=120)


def main():
    params = init_model(jax.random.PRNGKey(0), CFG)
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, CFG.vocab_size, P, dtype=np.int32)

    eng = make_engine(params, CFG, SERVE, prompt_len=P)
    eng.warmup(per_request=True)
    server = serve_http(eng, "127.0.0.1", 0, block=False)
    base = "http://127.0.0.1:%d" % server.server_address[1]

    # reference: identical engine, batch generate
    ref_eng = make_engine(params, CFG, SERVE, prompt_len=P)
    ref_eng.warmup()
    ref = ref_eng.generate([Request(prompt=prompt, id=0)])[0]
    ref_ids = np.asarray(ref.tokens)[:ref.gen_length].tolist()

    with urllib.request.urlopen(f"{base}/healthz", timeout=30) as r:
        assert json.load(r)["status"] == "ok"

    with _post(base, {"prompt": prompt.tolist()}) as r:
        full = json.load(r)
    got_full = full["choices"][0]["token_ids"]
    assert got_full == ref_ids, (got_full, ref_ids)
    print(f"non-streamed: {len(got_full)} tokens, "
          f"finish={full['choices'][0]['finish_reason']} — matches "
          "Engine.generate")

    got_stream, chunks = [], 0
    with _post(base, {"prompt": prompt.tolist(), "stream": True}) as r:
        for raw in r:
            line = raw.decode().strip()
            if not line.startswith("data: "):
                continue
            data = line[len("data: "):]
            if data == "[DONE]":
                break
            got_stream.extend(json.loads(data)["choices"][0]["token_ids"])
            chunks += 1
    assert got_stream == ref_ids, (got_stream, ref_ids)
    print(f"streamed: {chunks} block chunks reassemble to the same "
          f"{len(got_stream)} tokens")

    with urllib.request.urlopen(f"{base}/metrics", timeout=30) as r:
        metrics = r.read().decode()
    assert "cdlm_requests_completed_total 2" in metrics, metrics
    assert "cdlm_lanes_peak_lanes" in metrics
    print("metrics: requests_completed_total=2, lane/page gauges exported")

    server.shutdown()
    print("serve smoke OK")


if __name__ == "__main__":
    main()
