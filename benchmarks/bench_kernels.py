"""Kernel-layer microbenchmark: jit'd pure-jnp oracle vs the chunked
flash path at model shapes (the Pallas kernels themselves are validated in
interpret mode — timing them on CPU would measure the interpreter)."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import masks
from repro.models.layers import attention_core


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv_rows=None):
    print("\n== kernel-layer microbench (CPU, jnp paths) ==")
    key = jax.random.PRNGKey(0)
    b, Kv, G, hd = 1, 2, 4, 64
    for L in (512, 2048):
        q = jax.random.normal(key, (b, L, Kv, G, hd))
        k = jax.random.normal(key, (b, L, Kv, hd))
        v = jax.random.normal(key, (b, L, Kv, hd))
        pos = jnp.arange(L)
        bf = masks.make_bias_fn(mode="block_causal", prompt_len=64,
                                block_size=32)
        bfv = lambda qp, kp, val: bf(qp, kp)
        dense = jax.jit(lambda q, k, v: attention_core(
            q, k, v, q_pos=pos, kv_pos=pos, bias_fn=bfv, scale=0.125,
            impl="dense"))
        chunk = jax.jit(lambda q, k, v: attention_core(
            q, k, v, q_pos=pos, kv_pos=pos, bias_fn=bfv, scale=0.125,
            impl="chunked", chunk=512))
        td = _time(dense, q, k, v)
        tc = _time(chunk, q, k, v)
        print(f"  block-causal attn L={L:5d}: dense={td:9.0f}us "
              f"chunked={tc:9.0f}us")
        if csv_rows is not None:
            csv_rows.append((f"kernels/attn_dense_L{L}", td, ""))
            csv_rows.append((f"kernels/attn_chunked_L{L}", tc, ""))
    return csv_rows


if __name__ == "__main__":
    run()
