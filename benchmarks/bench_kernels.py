"""Kernel-layer microbenchmarks.

Attention: jit'd pure-jnp oracle vs the chunked flash path at model shapes
(the Pallas kernels themselves are validated in interpret mode — timing
them on CPU would measure the interpreter).

Select: the decode loop's per-step vocabulary cost. Baseline = dense
candidate selection (lm_head logits + fp32 softmax + argmax + gather, the
(T, V) round-trip ``repro.core.diffusion.confidence_and_candidates``
performs); fused = ``repro.kernels.select`` with ``impl='streaming'`` —
the same online statistics the Pallas kernel keeps in VMEM, expressed as a
jit-compiled vocab-chunked scan, so CPU timing reflects the algorithm's
memory behavior instead of the Pallas interpreter. Swept at Dream/LLaDA-
scale vocabs (V ∈ {32k, 128k}), where the baseline's (T, V) HBM round-trip
dominates a cached decode step.

    PYTHONPATH=src python -m benchmarks.bench_kernels
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke \
        --json BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import masks
from repro.kernels.select import fused_select, select_ref
from repro.models.layers import attention_core

SELECT_VOCABS = (32_768, 131_072)


def _time(fn, *args, iters=5):
    out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run_attention(csv_rows=None, smoke=False):
    print("\n== kernel-layer microbench: attention (CPU, jnp paths) ==")
    key = jax.random.PRNGKey(0)
    b, Kv, G, hd = 1, 2, 4, 64
    for L in ((512,) if smoke else (512, 2048)):
        q = jax.random.normal(key, (b, L, Kv, G, hd))
        k = jax.random.normal(key, (b, L, Kv, hd))
        v = jax.random.normal(key, (b, L, Kv, hd))
        pos = jnp.arange(L)
        bf = masks.make_bias_fn(mode="block_causal", prompt_len=64,
                                block_size=32)
        bfv = lambda qp, kp, val: bf(qp, kp)
        dense = jax.jit(lambda q, k, v: attention_core(
            q, k, v, q_pos=pos, kv_pos=pos, bias_fn=bfv, scale=0.125,
            impl="dense"))
        chunk = jax.jit(lambda q, k, v: attention_core(
            q, k, v, q_pos=pos, kv_pos=pos, bias_fn=bfv, scale=0.125,
            impl="chunked", chunk=512))
        td = _time(dense, q, k, v)
        tc = _time(chunk, q, k, v)
        print(f"  block-causal attn L={L:5d}: dense={td:9.0f}us "
              f"chunked={tc:9.0f}us")
        if csv_rows is not None:
            csv_rows.append((f"kernels/attn_dense_L{L}", td, ""))
            csv_rows.append((f"kernels/attn_chunked_L{L}", tc, ""))
    return csv_rows


def run_select(csv_rows=None, results=None, smoke=False):
    """Fused-vs-baseline candidate selection at decode-step shapes."""
    T, d = (32, 128) if smoke else (128, 512)
    iters = 3 if smoke else 5
    print(f"\n== kernel-layer microbench: fused select "
          f"(T={T} decode rows, d={d}) ==")
    print(f"  {'V':>8} {'baseline us':>12} {'fused us':>10} {'speedup':>8}")
    key = jax.random.PRNGKey(0)
    sel = {}
    for V in SELECT_VOCABS:
        ks = jax.random.split(key, 3)
        h = jax.random.normal(ks[0], (T, d), jnp.float32) * 0.5
        w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
        m = jax.random.bernoulli(ks[2], 0.7, (T,))
        # the dense decode-step selection ((T, V) logits + full fp32
        # softmax + argmax + gather) IS the kernel package's oracle
        base = jax.jit(select_ref, static_argnames=("softcap",))
        fused = jax.jit(lambda h, w, m: fused_select(
            h, w, m, impl="streaming", block_v=2048))
        tb = _time(base, h, w, m, iters=iters)
        tf = _time(fused, h, w, m, iters=iters)
        speedup = tb / tf if tf > 0 else float("inf")
        print(f"  {V:>8} {tb:>12.0f} {tf:>10.0f} {speedup:>7.2f}x")
        if csv_rows is not None:
            csv_rows.append((f"kernels/select_baseline_V{V}", tb, ""))
            csv_rows.append((f"kernels/select_fused_V{V}", tf,
                             f"{speedup:.2f}"))
        sel[f"V{V}"] = {"T": T, "d": d, "baseline_us": tb, "fused_us": tf,
                        "speedup": speedup}
    if results is not None:
        results["select"] = sel
    return sel


def run(csv_rows=None, smoke=False, results=None):
    run_attention(csv_rows, smoke=smoke)
    run_select(csv_rows=csv_rows, results=results, smoke=smoke)
    return csv_rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes (fewer rows/iters; same V sweep)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write benchmark numbers as JSON")
    args = ap.parse_args(argv)
    results = {"smoke": args.smoke, "select_vocabs": list(SELECT_VOCABS)}
    run(smoke=args.smoke, results=results)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
