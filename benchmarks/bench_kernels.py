"""Kernel-layer microbenchmarks.

Attention: jit'd pure-jnp oracle vs the chunked flash path at model shapes
(the Pallas kernels themselves are validated in interpret mode — timing
them on CPU would measure the interpreter).

Select: the decode loop's per-step vocabulary cost. Baseline = dense
candidate selection (lm_head logits + fp32 softmax + argmax + gather, the
(T, V) round-trip ``repro.core.diffusion.confidence_and_candidates``
performs); fused = ``repro.kernels.select`` with **no explicit knobs** —
exactly what the serving decode loop calls — so the timed path is the
jit-compiled impl/tile the tuned-config registry
(``repro.kernels.tuning``) resolves for this backend and vocab bucket.
Swept at Dream/LLaDA-scale vocabs (V ∈ {32k, 128k}), where the baseline's
(T, V) HBM round-trip dominates a cached decode step.

``--tune`` re-runs the registry's config sweep first and persists the
winners to ``src/repro/kernels/tuned_configs.json`` (the checked-in
table), then benches with the freshly tuned configs.

    PYTHONPATH=src python -m benchmarks.bench_kernels
    PYTHONPATH=src python -m benchmarks.bench_kernels --tune
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke \
        --json BENCH_kernels.json
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import masks
from repro.kernels import tuning
from repro.kernels.select import fused_select, select_ref
from repro.models.layers import attention_core

SELECT_VOCABS = (32_768, 131_072)


def _time(fn, *args, iters=5, repeats=3):
    """Best-of-``repeats`` average over ``iters`` calls — min-of-windows
    rejects scheduler/load noise that a single average folds in."""
    out = fn(*args)
    (out[0] if isinstance(out, tuple) else out).block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best


def run_attention(csv_rows=None, smoke=False, records=None):
    print("\n== kernel-layer microbench: attention (CPU, jnp paths) ==")
    key = jax.random.PRNGKey(0)
    b, Kv, G, hd = 1, 2, 4, 64
    for L in ((512,) if smoke else (512, 2048)):
        q = jax.random.normal(key, (b, L, Kv, G, hd))
        k = jax.random.normal(key, (b, L, Kv, hd))
        v = jax.random.normal(key, (b, L, Kv, hd))
        pos = jnp.arange(L)
        bf = masks.make_bias_fn(mode="block_causal", prompt_len=64,
                                block_size=32)
        bfv = lambda qp, kp, val: bf(qp, kp)
        dense = jax.jit(lambda q, k, v: attention_core(
            q, k, v, q_pos=pos, kv_pos=pos, bias_fn=bfv, scale=0.125,
            impl="dense"))
        chunk = jax.jit(lambda q, k, v: attention_core(
            q, k, v, q_pos=pos, kv_pos=pos, bias_fn=bfv, scale=0.125,
            impl="chunked", chunk=512))
        td = _time(dense, q, k, v)
        tc = _time(chunk, q, k, v)
        print(f"  block-causal attn L={L:5d}: dense={td:9.0f}us "
              f"chunked={tc:9.0f}us")
        if csv_rows is not None:
            csv_rows.append((f"kernels/attn_dense_L{L}", td, ""))
            csv_rows.append((f"kernels/attn_chunked_L{L}", tc, ""))
        if records is not None:
            shape = {"L": L, "b": b, "Kv": Kv, "G": G, "hd": hd}
            records.append(common.record(
                "attn", shape, "us_per_call", tc,
                config={"impl": "chunked", "chunk": 512}))
            records.append(common.record(
                "attn", shape, "us_per_call", td, config={"impl": "dense"}))
    return csv_rows


def run_select(csv_rows=None, results=None, smoke=False, records=None):
    """Fused-vs-baseline candidate selection at decode-step shapes.

    The fused call passes no knobs, so the timed config is whatever the
    tuned registry resolves — the number this prints is the number the
    serving decode loop gets."""
    T, d = (32, 128) if smoke else (128, 512)
    iters = 3 if smoke else 5
    print(f"\n== kernel-layer microbench: fused select "
          f"(T={T} decode rows, d={d}, tuned configs) ==")
    print(f"  {'V':>8} {'baseline us':>12} {'fused us':>10} {'speedup':>8} "
          "tuned config")
    key = jax.random.PRNGKey(0)
    sel = {}
    for V in SELECT_VOCABS:
        ks = jax.random.split(key, 3)
        h = jax.random.normal(ks[0], (T, d), jnp.float32) * 0.5
        w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
        m = jax.random.bernoulli(ks[2], 0.7, (T,))
        # the dense decode-step selection ((T, V) logits + full fp32
        # softmax + argmax + gather) IS the kernel package's oracle
        base = jax.jit(select_ref, static_argnames=("softcap",))
        cfg = tuning.resolve("select", V=V)
        fused = jax.jit(lambda h, w, m: fused_select(h, w, m))
        tb = _time(base, h, w, m, iters=iters)
        tf = _time(fused, h, w, m, iters=iters)
        speedup = tb / tf if tf > 0 else float("inf")
        cfg_d = {k: v for k, v in cfg.to_dict().items() if v is not None}
        print(f"  {V:>8} {tb:>12.0f} {tf:>10.0f} {speedup:>7.2f}x {cfg_d}")
        if csv_rows is not None:
            csv_rows.append((f"kernels/select_baseline_V{V}", tb, ""))
            csv_rows.append((f"kernels/select_fused_V{V}", tf,
                             f"{speedup:.2f}"))
        shape = {"T": T, "d": d, "V": V}
        if records is not None:
            records.append(common.record("select", shape, "us_per_call", tf,
                                         config=cfg_d))
            records.append(common.record("select", shape, "us_per_call", tb,
                                         config={"impl": "dense_ref"}))
            records.append(common.record("select", shape, "speedup_vs_dense",
                                         speedup, config=cfg_d))
        sel[f"V{V}"] = {"T": T, "d": d, "baseline_us": tb, "fused_us": tf,
                        "speedup": speedup, "config": cfg_d}
    if results is not None:
        results["select"] = sel
    return sel


def run(csv_rows=None, smoke=False, results=None):
    records = results.setdefault("records", []) if results is not None \
        else None
    run_attention(csv_rows, smoke=smoke, records=records)
    run_select(csv_rows=csv_rows, results=results, smoke=smoke,
               records=records)
    return csv_rows


def main(argv=None):
    ap = common.make_parser(
        description=__doc__,
        smoke_help="CI-sized shapes (fewer rows/iters; same V sweep)")
    ap.add_argument("--tune", action="store_true",
                    help="re-run the kernel config sweep and persist the "
                         "winners to the checked-in tuned table before "
                         "benchmarking")
    ap.add_argument("--tune-ops", default=None, metavar="OP[,OP...]",
                    help="restrict --tune to these ops "
                         f"(default: all of {sorted(tuning.OP_DEFAULTS)})")
    args = ap.parse_args(argv)
    if args.tune:
        ops = tuple(args.tune_ops.split(",")) if args.tune_ops else None
        tuning.run_sweep(ops, vocabs=SELECT_VOCABS,
                         iters=3 if args.smoke else 5)
        tuning.clear_cache()
    results = {"smoke": args.smoke, "select_vocabs": list(SELECT_VOCABS),
               "records": []}
    run(smoke=args.smoke, results=results)
    common.write_results(args.json, results)


if __name__ == "__main__":
    main()
