"""Shared benchmark assets and CLI plumbing.

Assets: one tiny teacher + CDLM student trained once and cached under
experiments/bench_assets/, reused by every table benchmark.

CLI: every benchmark entry point builds its parser with
:func:`make_parser` (the shared ``--smoke``/``--json`` surface) and writes
its numbers with :func:`write_results`; cross-benchmark comparisons (the
per-PR trajectory in ``benchmarks.trajectory``) consume the shared
result-record schema produced by :func:`record` —
``{op, shape, backend, metric, value, config}``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs.base import CDLMConfig, TrainConfig
from repro.configs.registry import get_config
from repro.data import Corpus, TaskSpec
from repro.data.synthetic import score
from repro.models import init_model
from repro.training import trainer

ASSETS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "bench_assets")


# ---------------------------------------------------------------------------
# shared CLI + result-record schema
# ---------------------------------------------------------------------------
def make_parser(description=None,
                smoke_help="CI-sized shapes/traces (random-init params "
                           "where applicable)"):
    """The argparse surface every benchmark shares: ``--smoke`` and an
    explicit ``--json PATH`` (benchmarks never write artifacts to implicit
    locations — stray ``BENCH_*.json`` at the repo root are gitignored)."""
    ap = argparse.ArgumentParser(
        description=description,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true", help=smoke_help)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write benchmark numbers as JSON to PATH")
    return ap


def record(op, shape, metric, value, *, backend=None, config=None):
    """One schema'd result record — the unit ``benchmarks.trajectory``
    tracks across PRs. ``shape``/``config`` are plain dicts; ``backend``
    defaults to the active jax backend."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    return {"op": str(op), "shape": dict(shape or {}),
            "backend": str(backend), "metric": str(metric),
            "value": float(value), "config": dict(config or {})}


def write_results(path, results):
    """Write a benchmark's ``--json`` artifact (stable key order)."""
    if not path:
        return
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")

CFG = get_config("qwen2-0.5b").reduced(
    n_layers=2, d_model=128, d_ff=256, vocab_size=128, mask_token_id=127)
TASK = TaskSpec("sort", vocab_size=128, prompt_len=10, gen_len=10,
                sort_k=8, sort_range=24)
CDLM_CFG = CDLMConfig(block_size=5, gen_length=10, prompt_length=10,
                      temperatures=(0.0, 0.5))
TEACHER_STEPS = 800
STUDENT_STEPS = 350


def corpus():
    return Corpus(TASK, 1024, seed=0)


def _path(name):
    os.makedirs(ASSETS, exist_ok=True)
    return os.path.join(ASSETS, name)


def get_teacher(verbose=False):
    template = init_model(jax.random.PRNGKey(0), CFG)
    p = _path("teacher.npz")
    if os.path.exists(p):
        return restore(template, p)
    tcfg = TrainConfig(learning_rate=2e-3, steps=TEACHER_STEPS,
                       batch_size=64, remat=False)
    teacher = trainer.train_teacher(CFG, corpus(), tcfg, verbose=verbose)
    save(teacher, p)
    return teacher


def get_dataset(teacher, verbose=False):
    p = _path("trajectories.npz")
    keys = ["prompt", "gt", "final", "finalized_at", "hidden"]
    if os.path.exists(p):
        with np.load(p) as d:
            return {k: jnp.asarray(d[k]) for k in keys}
    ds = trainer.collect_dataset(teacher, CFG, CDLM_CFG, corpus(),
                                 n_examples=256, batch=64, verbose=verbose)
    np.savez(p, **{k: np.asarray(v) for k, v in ds.items()})
    return ds


def get_student(teacher=None, dataset=None, *, weights=None, steps=None,
                cache_name="student.npz", verbose=False):
    template = init_model(jax.random.PRNGKey(0), CFG)
    p = _path(cache_name)
    if os.path.exists(p):
        return restore(template, p)
    teacher = teacher if teacher is not None else get_teacher()
    dataset = dataset if dataset is not None else get_dataset(teacher)
    cdlm = CDLM_CFG
    if weights is not None:
        wd, wc, wm = weights
        cdlm = dataclasses.replace(CDLM_CFG, w_distill=wd, w_cons=wc,
                                   w_dlm=wm)
    scfg = TrainConfig(learning_rate=5e-4, steps=steps or STUDENT_STEPS,
                       batch_size=64, remat=False)
    student = trainer.train_student(teacher, dataset, CFG, cdlm, scfg,
                                    verbose=verbose)
    save(student, p)
    return student


def poisson_trace(n=48, rate_hz=60.0, seed=0, short_frac=0.5,
                  sampled_frac=0.0):
    """Serving-bench request trace: Poisson arrivals over the eval split with
    mixed per-request generation caps (a ``short_frac`` share capped at one
    block, the rest at the full ``gen_len``). A ``sampled_frac`` share
    carries per-request ``SamplingParams`` (temperature 0.7, own seed), so
    the trace exercises mixed greedy/sampled continuous batches."""
    from repro.serving import Request, SamplingParams
    rng = np.random.default_rng(seed)
    # separate stream for the sampled-lane draws: the arrival/max_tokens
    # mix at a given seed stays identical to previously recorded traces
    # (BENCH_serving.json trajectories) regardless of sampled_frac
    srng = np.random.default_rng(seed + 0x5EED)
    ev = corpus().eval_batch(n)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    B = CDLM_CFG.block_size
    reqs = []
    for i in range(n):
        mt = B if rng.random() < short_frac else TASK.gen_len
        sp = (SamplingParams(temperature=0.7, seed=i)
              if srng.random() < sampled_frac else None)
        reqs.append(Request(prompt=ev["prompt"][i], id=i, max_tokens=int(mt),
                            arrival_s=float(arrivals[i]), params=sp))
    return reqs


def eval_sampler(params, sampler_fn, *, n=64, conf_threshold=0.9,
                 block_size=None, temperature=0.0, early_stop=False,
                 **extra):
    """Run a sampler over the eval split; return the Tables-1/2 columns."""
    from repro.core.sampler import SamplerSpec
    ev = corpus().eval_batch(n)
    prompts = jnp.asarray(ev["prompt"])
    spec = SamplerSpec(prompt_len=TASK.prompt_len, gen_len=TASK.gen_len,
                       block_size=block_size or CDLM_CFG.block_size,
                       conf_threshold=conf_threshold,
                       temperature=temperature, early_stop=early_stop)
    jfn = jax.jit(lambda p, x: sampler_fn(p, x, cfg=CFG, spec=spec, **extra))
    res = jfn(params, prompts)
    res.tokens.block_until_ready()           # warm
    t0 = time.perf_counter()
    res = jfn(params, prompts)
    res.tokens.block_until_ready()
    dt = time.perf_counter() - t0
    s = score(ev["prompt"], np.asarray(res.tokens), TASK.prompt_len, TASK)
    steps = float(res.steps.mean())
    glen = float(res.gen_lengths.mean())
    lat = dt / n
    return {"score": s, "steps": steps, "gen_len": glen,
            "latency_s": lat, "tps": glen / lat if lat else 0.0,
            "calls": int(res.n_model_calls)}
