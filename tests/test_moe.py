"""MoE dispatch correctness: scatter-dispatch == dense-all-experts oracle
when capacity is not binding; aux-loss behavior; dropless decode."""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import moe as MO


def _cfg(**kw):
    return get_config("kimi-k2-1t-a32b").reduced(**kw)


def test_dispatch_matches_dense_when_dropless():
    cfg = _cfg(dtype="float32")
    params = MO.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    out, aux = MO.apply_moe(params, x, cfg, dropless=True)
    ref = MO.apply_moe_dense_fallback(params, x, cfg)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
    assert float(aux) > 0


def test_capacity_drops_tokens_gracefully():
    cfg = _cfg(dtype="float32")
    params = MO.init_moe(jax.random.PRNGKey(0), cfg)
    # force one dominant expert: huge router bias toward expert 0
    params = dict(params)
    params["router"] = params["router"].at[:, 0].add(100.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, _ = MO.apply_moe(params, x, cfg, dropless=False)
    assert bool(jnp.isfinite(out).all())
    # dropless output differs (no tokens dropped)
    out2, _ = MO.apply_moe(params, x, cfg, dropless=True)
    assert float(jnp.max(jnp.abs(out - out2))) > 0


def test_aux_loss_balanced_routing_is_minimal():
    """Uniform router -> aux ~= K (its minimum under top-k one-hot counts:
    E * sum_e (K/E)(1/E) * E = K)."""
    cfg = _cfg(dtype="float32")
    params = MO.init_moe(jax.random.PRNGKey(0), cfg)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    _, aux = MO.apply_moe(params, x, cfg)
    K = cfg.experts_per_token
    assert K * 0.9 < float(aux) < K * 1.6
    # ...and an imbalanced router is strictly worse
    params["router"] = params["router"].at[:, 0].add(100.0)
    _, aux_bad = MO.apply_moe(params, x, cfg)
    assert float(aux_bad) > float(aux)


def test_gate_normalization():
    cfg = _cfg(dtype="float32")
    params = MO.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((1, 4, cfg.d_model))
    out, _ = MO.apply_moe(params, x, cfg, dropless=True)
    # zero input -> experts see zeros -> output only from biases (~0)
    assert float(jnp.max(jnp.abs(out))) < 1e-3


def test_shared_expert_contributes():
    cfg = _cfg(dtype="float32")
    assert cfg.n_shared_experts == 1
    params = MO.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    full, _ = MO.apply_moe(params, x, cfg, dropless=True)
    p2 = dict(params)
    p2["shared"] = jax.tree_util.tree_map(jnp.zeros_like, params["shared"])
    nosh, _ = MO.apply_moe(p2, x, cfg, dropless=True)
    assert float(jnp.max(jnp.abs(full - nosh))) > 1e-4
