"""Request-level serving API: per-request SamplingParams (mixed batches
bit-identical to isolated decodes, dense and paged layouts), exact
block-at-a-time streaming (``stream()`` reassembles to ``generate()``),
mid-flight ``abort()``, engine-assigned ids, and the unified
``warmup(extras=None)`` surface."""
import jax
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.configs.registry import get_config
from repro.serving import (
    ContinuousEngine,
    Engine,
    GenerationRequest,
    Request,
    SamplingParams,
    make_engine,
)

CFG = get_config("qwen2-0.5b").reduced(dtype="float32")
P, G, B = 8, 16, 4


def _serve(scheduler="continuous", max_batch=2, sampler="cdlm", **kw):
    return ServeConfig(max_batch=max_batch, block_size=B, gen_length=G,
                       sampler=sampler, conf_threshold=0.5,
                       scheduler=scheduler, **kw)


@pytest.fixture(scope="module")
def params():
    from repro.models import init_model
    return init_model(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(2, CFG.vocab_size, P, dtype=np.int32)
            for _ in range(5)]


def _mixed_requests(prompts):
    """Greedy, sampled (explicit + default seed) and per-request-τ lanes
    sharing one batch."""
    sp = [SamplingParams(),
          SamplingParams(temperature=0.9, seed=7),
          SamplingParams(conf_threshold=0.8),
          SamplingParams(temperature=0.5),
          SamplingParams(temperature=0.7, conf_threshold=0.6, seed=3)]
    return [Request(prompt=p, id=i, params=s)
            for i, (p, s) in enumerate(zip(prompts, sp))]


# ---------------------------------------------------------------------------
# Mixed per-request params == isolated decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_continuous_mixed_params_match_isolated(params, prompts, layout):
    """THE per-request invariant: a continuous batch mixing greedy and
    sampled lanes (different temperatures, thresholds, seeds) decodes
    every lane bit-identically to that request served alone — per-lane
    RNG streams advance only on the lane's own refinement iterations."""
    eng = ContinuousEngine(params, CFG, _serve(cache_layout=layout),
                           prompt_len=P)
    eng.warmup()
    reqs = _mixed_requests(prompts)
    batched = {r.id: r for r in eng.generate(list(reqs))}
    assert sorted(batched) == [0, 1, 2, 3, 4]
    for req in reqs:
        solo = eng.generate([Request(prompt=req.prompt, id=req.id,
                                     params=req.params)])[0]
        got = batched[req.id]
        assert np.array_equal(solo.tokens, got.tokens), req.id
        assert solo.steps == got.steps, req.id
        assert solo.gen_length == got.gen_length, req.id
        assert solo.finish_reason == got.finish_reason, req.id


def test_static_mixed_params_match_isolated(params, prompts):
    """The static engine threads the same per-lane (b,) params through
    the jitted threshold loop."""
    eng = Engine(params, CFG, _serve("static", max_batch=4), prompt_len=P)
    reqs = _mixed_requests(prompts)[:4]
    batched = {r.id: r for r in eng.generate(list(reqs))}
    for req in reqs:
        solo = eng.generate([Request(prompt=req.prompt, id=req.id,
                                     params=req.params)])[0]
        got = batched[req.id]
        assert np.array_equal(solo.tokens, got.tokens), req.id
        assert solo.steps == got.steps, req.id


def test_sampled_seed_controls_stream(params, prompts):
    """Same seed -> same sample; different seed -> (here) different
    tokens; temperature=0 ignores the seed."""
    eng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    eng.warmup()

    def run(sp):
        return eng.generate([Request(prompt=prompts[0], id=0, params=sp)])[0]

    a = run(SamplingParams(temperature=0.9, seed=11))
    b = run(SamplingParams(temperature=0.9, seed=11))
    c = run(SamplingParams(temperature=0.9, seed=12))
    assert np.array_equal(a.tokens, b.tokens)
    assert not np.array_equal(a.tokens, c.tokens)
    g1 = run(SamplingParams(seed=11))
    g2 = run(SamplingParams(seed=12))
    assert np.array_equal(g1.tokens, g2.tokens)


def test_per_request_params_rejected_for_nonthreshold(params, prompts):
    """Rejected at add_request time, so a server can 400 the one bad
    request instead of failing the shared decode step."""
    eng = Engine(params, CFG, _serve("static", max_batch=2, sampler="ar"),
                 prompt_len=P)
    req = Request(prompt=prompts[0], id=0,
                  params=SamplingParams(temperature=0.5))
    with pytest.raises(ValueError, match="threshold"):
        eng.add_request(req)
    with pytest.raises(ValueError, match="threshold"):
        eng.generate([Request(prompt=prompts[0], id=0,
                              params=SamplingParams(temperature=0.5))])


def test_fused_select_engine_is_greedy_only(params, prompts):
    """fused_select engines reject sampled requests up front: a sampled
    lane would silently flip greedy chunk-mates from the fused kernel to
    the dense selection path (last-ULP confidence differences could break
    isolated-decode exactness)."""
    eng = ContinuousEngine(params, CFG, _serve(fused_select=True),
                           prompt_len=P)
    with pytest.raises(ValueError, match="greedy"):
        eng.add_request(Request(prompt=prompts[0],
                                params=SamplingParams(temperature=0.5)))
    # greedy per-request knobs (threshold, eos, cap) remain fine — they
    # never change which selection path runs
    out = eng.generate([Request(
        prompt=prompts[0], id=0,
        params=SamplingParams(conf_threshold=0.8))])[0]
    solo = ContinuousEngine(params, CFG, _serve(), prompt_len=P).generate(
        [Request(prompt=prompts[0], id=0,
                 params=SamplingParams(conf_threshold=0.8))])[0]
    assert np.array_equal(out.tokens, solo.tokens)
    with pytest.raises(ValueError, match="greedy-only"):
        ContinuousEngine(params, CFG,
                         _serve(fused_select=True, temperature=0.7),
                         prompt_len=P)


# ---------------------------------------------------------------------------
# Streaming
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_stream_reassembles_to_generate(params, prompts, scheduler):
    """Concatenating a request's BlockEvents reproduces its generate()
    span token-for-token; the final event carries the same output."""
    eng = make_engine(params, CFG, _serve(scheduler), prompt_len=P)
    eng.warmup()
    reqs = [Request(prompt=p, id=i) for i, p in enumerate(prompts)]
    want = {r.id: r for r in eng.generate(list(reqs))}

    got_blocks, got_out = {}, {}
    for ev in eng.stream([Request(prompt=p, id=i)
                          for i, p in enumerate(prompts)]):
        assert ev.tokens.shape == (B,)
        assert ev.start == ev.index * B
        blocks = got_blocks.setdefault(ev.request_id, [])
        assert ev.index == len(blocks)  # in-order, no gaps
        blocks.append(ev.tokens)
        if ev.finished:
            got_out[ev.request_id] = ev.output
    assert sorted(got_out) == sorted(want)
    for rid, out in got_out.items():
        ref = want[rid]
        assert np.array_equal(out.tokens, ref.tokens)
        assert out.steps == ref.steps
        assert out.gen_length == ref.gen_length
        span = np.concatenate(got_blocks[rid])
        assert np.array_equal(span, np.asarray(ref.tokens)[:len(span)])
        assert len(span) >= ref.gen_length


def test_stream_no_duplicate_blocks_under_preemption(params, prompts):
    """A page-starved pool preempts lanes, and preempted requests re-decode
    from scratch — but their already-streamed blocks must not be re-emitted
    (the re-decode is bit-identical, so dedup by block index is exact)."""
    T = P + G
    eng = ContinuousEngine(
        params, CFG,
        _serve(cache_layout="paged", page_pool_pages=T // B + 2),
        prompt_len=P)
    eng.warmup()
    reqs = [Request(prompt=p, id=i) for i, p in enumerate(prompts)]
    want = {r.id: r for r in eng.generate(list(reqs))}
    assert eng.page_pool_stats()["preemptions"] \
        + eng.page_pool_stats()["stall_rounds"] > 0

    seen, blocks, outs = set(), {}, {}
    for ev in eng.stream([Request(prompt=p, id=i)
                          for i, p in enumerate(prompts)]):
        assert (ev.request_id, ev.index) not in seen
        seen.add((ev.request_id, ev.index))
        assert ev.index == len(blocks.setdefault(ev.request_id, []))
        blocks[ev.request_id].append(ev.tokens)
        if ev.finished:
            outs[ev.request_id] = ev.output
    assert sorted(outs) == sorted(want)
    for rid, out in outs.items():
        assert np.array_equal(out.tokens, want[rid].tokens), rid
        span = np.concatenate(blocks[rid])
        assert np.array_equal(span, np.asarray(out.tokens)[:len(span)])


def test_stream_early_exit_does_not_wedge_engine(params, prompts):
    """Abandoning a stream mid-way (break / generator close) aborts its
    leftover requests, so the engine isn't stuck 'busy' forever."""
    eng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    eng.warmup()
    it = eng.stream([Request(prompt=p, id=i)
                     for i, p in enumerate(prompts[:3])])
    next(it)
    it.close()
    assert not eng.has_unfinished()
    out = eng.generate([Request(prompt=prompts[0], id=0)])
    assert len(out) == 1


def test_incremental_add_step_matches_generate(params, prompts):
    """Driving add_request()/step()/has_unfinished() by hand is the same
    computation generate() drains."""
    eng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    eng.warmup()
    want = {r.id: r for r in eng.generate(
        [Request(prompt=p, id=i) for i, p in enumerate(prompts)])}
    eng._reset()
    for i, p in enumerate(prompts):
        eng.add_request(Request(prompt=p, id=i))
    out = {}
    while eng.has_unfinished():
        for ev in eng.step():
            if ev.finished:
                out[ev.request_id] = ev.output
    assert sorted(out) == sorted(want)
    for rid in want:
        assert np.array_equal(out[rid].tokens, want[rid].tokens)


# ---------------------------------------------------------------------------
# Abort
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_abort_frees_lane_without_perturbing_survivors(params, prompts,
                                                       layout):
    """Aborting an in-flight request evicts its lane (paged: returns its
    pages) and the queued request takes the slot; every surviving request
    still decodes bit-identically to its isolated decode."""
    from repro.core import cache as C
    eng = ContinuousEngine(params, CFG, _serve(cache_layout=layout),
                           prompt_len=P)
    eng.warmup()
    solo = {}
    for i, p in enumerate(prompts[:3]):
        solo[i] = eng.generate([Request(prompt=p, id=i)])[0]

    eng._reset()
    for i, p in enumerate(prompts[:3]):  # 3 requests, 2 lanes
        eng.add_request(Request(prompt=p, id=i))
    out = {}
    first = eng.step()  # requests 0 and 1 advance one block
    assert {ev.request_id for ev in first} == {0, 1}
    assert eng.abort(1)          # mid-flight
    assert not eng.abort(99)     # unknown id
    while eng.has_unfinished():
        for ev in eng.step():
            if ev.finished:
                out[ev.request_id] = ev.output
    assert sorted(out) == [0, 2]  # aborted request never completes
    for rid in out:
        assert np.array_equal(out[rid].tokens, solo[rid].tokens), rid
        assert out[rid].steps == solo[rid].steps, rid
    if layout == "paged":
        # every page went back to the pool
        free = int(np.asarray(C.free_page_count(eng._state.cache)))
        assert free == eng.n_pages


def test_abort_queued_request(params, prompts):
    eng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    eng.warmup()
    eng._reset()
    rid = eng.add_request(Request(prompt=prompts[0]))
    assert eng.abort(rid)
    assert not eng.has_unfinished()


# ---------------------------------------------------------------------------
# Request ids
# ---------------------------------------------------------------------------
def test_engine_assigns_unique_monotonic_ids(params, prompts):
    eng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    eng.warmup()
    resp = eng.generate([Request(prompt=p) for p in prompts[:3]])
    assert sorted(r.id for r in resp) == [0, 1, 2]
    # later calls keep counting up — ids stay unique per engine
    resp2 = eng.generate([Request(prompt=prompts[0])])
    assert resp2[0].id == 3


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_duplicate_explicit_ids_rejected(params, prompts, scheduler):
    eng = make_engine(params, CFG, _serve(scheduler), prompt_len=P)
    reqs = [Request(prompt=prompts[0], id=5), Request(prompt=prompts[1], id=5)]
    with pytest.raises(ValueError, match="duplicate"):
        list(eng.stream(reqs))


def test_auto_ids_skip_explicit_ones(params, prompts):
    eng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    eng.warmup()
    resp = eng.generate([Request(prompt=prompts[0], id=0),
                         Request(prompt=prompts[1])])
    assert sorted(r.id for r in resp) == [0, 1]


# ---------------------------------------------------------------------------
# Satellites: max_tokens slicing, warmup unification, eos override
# ---------------------------------------------------------------------------
def test_static_max_tokens_slices_tokens(params, prompts):
    """The static engine returns the *trimmed* token span for capped
    requests (it used to trim only the reported gen_length)."""
    eng = Engine(params, CFG, _serve("static"), prompt_len=P)
    capped, full = eng.generate([
        Request(prompt=prompts[0], id=0, max_tokens=B),
        Request(prompt=prompts[1], id=1)])
    assert capped.tokens.shape == (B,)
    assert capped.gen_length <= B
    assert full.tokens.shape == (G,)
    # params.max_tokens spells the same cap
    via_params = eng.generate([Request(
        prompt=prompts[0], id=0,
        params=SamplingParams(max_tokens=B))])[0]
    assert np.array_equal(via_params.tokens, capped.tokens)
    # streaming honors the cap too: one block, not the whole grid
    evs = list(eng.stream([Request(prompt=prompts[0], id=0, max_tokens=B)]))
    assert [ev.index for ev in evs] == [0]
    assert evs[-1].finished


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_warmup_signature_unified(params, scheduler):
    """make_engine callers pass warmup(extras=None) without branching on
    the engine type."""
    eng = make_engine(params, CFG, _serve(scheduler), prompt_len=P)
    eng.warmup(extras=None)
    assert eng._warm
    if scheduler == "continuous":
        with pytest.raises(ValueError, match="extras"):
            eng.warmup(extras={"encoder_embeds": np.zeros((1, 2))})


def test_eos_override_stops_early(params, prompts):
    eng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    eng.warmup()
    base = eng.generate([Request(prompt=prompts[0], id=0)])[0]
    stop_tok = int(np.asarray(base.tokens)[0])  # guaranteed to be emitted
    resp = eng.generate([Request(
        prompt=prompts[0], id=0,
        params=SamplingParams(eos_token_id=stop_tok))])[0]
    assert resp.finish_reason == "stop"
    assert resp.gen_length == 0  # stop token is the very first generated one
    # the decode itself is unchanged up to the stop block
    assert np.array_equal(np.asarray(resp.tokens)[:B],
                          np.asarray(base.tokens)[:B])


def test_generation_request_alias_and_finish_reason(params, prompts):
    """GenerationRequest is the canonical spelling; uncapped toy decodes
    exhaust the canvas -> "length"."""
    eng = Engine(params, CFG, _serve("static"), prompt_len=P)
    resp = eng.generate([GenerationRequest(prompt=prompts[0], id=0)])[0]
    assert resp.finish_reason in ("stop", "length")
    assert resp.tokens.shape == (G,)
