"""Fallback for the optional ``hypothesis`` test dependency.

``hypothesis`` is declared as an optional test extra (``repro[test]``);
when it is installed the real library is re-exported unchanged. When it is
absent, a small deterministic stand-in drives each property test with the
strategy's boundary values first, then seeded pseudo-random draws, so the
tier-1 suite stays runnable (and still exercises the edge cases hypothesis
would prioritize) without the dependency.

Test modules import through this shim::

    from _hypothesis_compat import given, settings, strategies as st
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random as _random

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A value generator: boundary examples first, then random draws."""

        def __init__(self, draw_fn, boundary=()):
            self._draw_fn = draw_fn
            self._boundary = tuple(boundary)

        def example(self, rng, i):
            if i < len(self._boundary):
                return self._boundary[i]
            return self._draw_fn(rng, i)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng, i: rng.randint(min_value, max_value),
                             boundary=(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng, i: rng.uniform(min_value, max_value),
                             boundary=(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng, i: bool(rng.getrandbits(1)),
                             boundary=(False, True))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng, i: rng.choice(elements),
                             boundary=elements[:1])

        @staticmethod
        def composite(fn):
            # the example index is shared with nested draws, so passes 0/1
            # automatically draw every inner strategy's min/max boundary.
            def strategy_factory(*args, **kwargs):
                def draw_value(rng, i):
                    return fn(lambda s: s.example(rng, i), *args, **kwargs)
                return _Strategy(draw_value)
            return strategy_factory

    strategies = _StrategiesModule()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(test_fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = _random.Random(0)
                for i in range(n):
                    vals = [s.example(rng, i) for s in strats]
                    test_fn(*vals)
            wrapper.__name__ = test_fn.__name__
            wrapper.__doc__ = test_fn.__doc__
            wrapper.__module__ = test_fn.__module__
            wrapper._max_examples = getattr(test_fn, "_max_examples",
                                            _DEFAULT_MAX_EXAMPLES)
            return wrapper
        return deco
