"""Continuous block-level batching engine: scheduling behavior and THE
serving invariant — mid-flight lane recycling is loss-free (a request
admitted into a freed lane decodes exactly as it would in isolation)."""
import jax
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.configs.registry import get_config
from repro.serving import ContinuousEngine, Engine, Request, make_engine

CFG = get_config("qwen2-0.5b").reduced(dtype="float32")
P, G, B = 8, 16, 4


def _serve(scheduler="continuous", max_batch=2, sampler="cdlm"):
    return ServeConfig(max_batch=max_batch, block_size=B, gen_length=G,
                       sampler=sampler, conf_threshold=0.5,
                       scheduler=scheduler)


@pytest.fixture(scope="module")
def params():
    from repro.models import init_model
    return init_model(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def requests():
    rng = np.random.default_rng(0)
    return [Request(prompt=rng.integers(2, CFG.vocab_size, P,
                                        dtype=np.int32), id=i)
            for i in range(5)]


def test_empty_request_list(params):
    eng = Engine(params, CFG, _serve("static"), prompt_len=P)
    assert eng.generate([]) == []
    ceng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    assert ceng.generate([]) == []


def test_mismatched_extras_raise(params):
    eng = Engine(params, CFG, _serve("static"), prompt_len=P)
    reqs = [Request(prompt=np.zeros(P, np.int32), id=0,
                    extras={"encoder_embeds": np.zeros((3, 4))}),
            Request(prompt=np.zeros(P, np.int32), id=1)]
    with pytest.raises(ValueError, match="extras"):
        eng.generate(reqs)


def test_continuous_requires_cdlm(params):
    with pytest.raises(ValueError, match="cdlm"):
        ContinuousEngine(params, CFG, _serve(sampler="fast_dllm"),
                         prompt_len=P)


def test_continuous_sampled_decoding_is_isolation_exact(params, requests):
    """Sampled decoding runs on per-lane RNG streams (advanced only on a
    lane's own active iterations), so a sampled request decodes
    bit-identically to its isolated decode regardless of batch company."""
    serve = ServeConfig(max_batch=2, block_size=B, gen_length=G,
                        sampler="cdlm", conf_threshold=0.5,
                        scheduler="continuous", temperature=0.7)
    eng = ContinuousEngine(params, CFG, serve, prompt_len=P)
    eng.warmup()
    batched = {r.id: r for r in eng.generate(list(requests))}
    for req in requests[:3]:
        solo = eng.generate([Request(prompt=req.prompt, id=req.id)])[0]
        got = batched[req.id]
        assert np.array_equal(solo.tokens, got.tokens), req.id
        assert solo.steps == got.steps, req.id


def test_make_engine_dispatch(params):
    assert isinstance(make_engine(params, CFG, _serve("static"),
                                  prompt_len=P), Engine)
    assert isinstance(make_engine(params, CFG, _serve("continuous"),
                                  prompt_len=P), ContinuousEngine)
    with pytest.raises(ValueError, match="scheduler"):
        make_engine(params, CFG, _serve("bogus"), prompt_len=P)


def test_continuous_serves_more_requests_than_lanes(params, requests):
    """5 requests through 2 lanes: every request completes exactly once,
    with queueing visible in the accounting."""
    eng = ContinuousEngine(params, CFG, _serve(max_batch=2), prompt_len=P)
    eng.warmup()
    resp = eng.generate(requests)
    assert sorted(r.id for r in resp) == [0, 1, 2, 3, 4]
    for r in resp:
        assert r.tokens.shape == (G,)
        assert 0 < r.gen_length <= G
        assert r.latency_s >= r.queue_s >= 0.0
    # at least one request had to wait for a lane
    assert max(r.queue_s for r in resp) > 0.0


def test_mid_flight_eviction_is_exact(params, requests):
    """THE invariant: a request admitted into a recycled lane (mid-flight,
    after a short request freed it) produces exactly the tokens and steps it
    produces when decoded alone — cache-row reset leaves no residue."""
    eng = ContinuousEngine(params, CFG, _serve(max_batch=2), prompt_len=P)
    eng.warmup()
    # short requests (1 block) finish first and free lanes for the rest
    mixed = [Request(prompt=r.prompt, id=r.id,
                     max_tokens=B if r.id < 2 else None) for r in requests]
    stream = {r.id: r for r in eng.generate(mixed)}
    for req in mixed:
        solo = eng.generate([Request(prompt=req.prompt, id=req.id,
                                     max_tokens=req.max_tokens)])[0]
        got = stream[req.id]
        assert np.array_equal(solo.tokens, got.tokens), req.id
        assert solo.steps == got.steps, req.id
        assert solo.gen_length == got.gen_length, req.id


def test_max_tokens_caps_generation(params, requests):
    eng = ContinuousEngine(params, CFG, _serve(max_batch=2), prompt_len=P)
    eng.warmup()
    resp = eng.generate([Request(prompt=requests[0].prompt, id=0,
                                 max_tokens=B)])
    assert resp[0].gen_length <= B
    # the returned span is sliced to the cap (same contract as the
    # static engine — no [MASK] filler past max_tokens)
    assert resp[0].tokens.shape == (B,)


def test_arrival_trace_ordering(params, requests):
    """Requests arriving later are admitted later (queue_s reflects the
    trace), and everything still completes."""
    eng = ContinuousEngine(params, CFG, _serve(max_batch=2), prompt_len=P)
    eng.warmup()
    staggered = [Request(prompt=r.prompt, id=r.id,
                         arrival_s=0.05 * r.id) for r in requests]
    resp = eng.generate(staggered)
    assert sorted(r.id for r in resp) == [0, 1, 2, 3, 4]
    assert all(r.latency_s >= 0 for r in resp)
