"""Fused unembed + online-softmax select kernel (repro.kernels.select):
exactness sweeps vs the dense oracle and the baseline diffusion path,
end-to-end token identity of fused-select decoding, and the structural
guarantee that the fused decode step never materializes a (b, ·, V) logits
tensor (asserted on the traced jaxpr)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.configs.registry import get_config
from repro.core import diffusion as D
from repro.core.block_loop import SamplerSpec
from repro.core.sampler import SAMPLERS
from repro.kernels.select import fused_select, select_ref
from repro.models import init_model
from repro.serving import ContinuousEngine, Request

CFG = get_config("qwen2-0.5b").reduced(dtype="float32")
P, G, B = 8, 16, 4

IMPLS = ("pallas", "streaming")


def _inputs(T, d, V, key=0, scale=0.5):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    h = jax.random.normal(ks[0], (T, d)) * scale
    w = jax.random.normal(ks[1], (d, V)) * 0.1
    masked = jax.random.bernoulli(ks[2], 0.7, (T,))
    return h, w, masked


def _check(h, w, masked, softcap, impl, tol=1e-6):
    rc, rf = select_ref(h.astype(jnp.float32), w.astype(jnp.float32), masked,
                        softcap=softcap)
    c, f = fused_select(h, w, masked, softcap=softcap, impl=impl,
                        interpret=True)
    assert np.array_equal(np.asarray(c), np.asarray(rc))
    assert np.array_equal(np.isneginf(np.asarray(f)),
                          np.isneginf(np.asarray(rf)))
    finite = np.isfinite(np.asarray(rf))
    diff = np.abs(np.asarray(f)[finite] - np.asarray(rf)[finite])
    assert diff.size == 0 or float(diff.max()) < tol


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("T,d,V,softcap", [
    (64, 32, 512, None),       # tile-divisible vocab
    (64, 32, 593, None),       # vocab not divisible by the tile
    (40, 48, 1000, 30.0),      # ragged rows + softcap
    (8, 16, 100, None),        # vocab smaller than one tile
    (200, 64, 2048, 50.0),     # multi-tile rows and vocab
])
def test_select_vs_oracle(T, d, V, softcap, impl):
    h, w, masked = _inputs(T, d, V, key=T + V)
    _check(h, w, masked, softcap, impl)


@pytest.mark.parametrize("impl", IMPLS)
def test_select_bf16_hidden(impl):
    h, w, masked = _inputs(96, 64, 700, key=7)
    h = h.astype(jnp.bfloat16)
    w = w.astype(jnp.bfloat16)
    rc, _ = select_ref(h.astype(jnp.float32), w.astype(jnp.float32), masked)
    c, f = fused_select(h, w, masked, impl=impl, interpret=True)
    # fp32 accumulation over bf16 inputs: candidates exact, conf close
    assert np.array_equal(np.asarray(c), np.asarray(rc))
    assert np.all(np.asarray(f)[np.asarray(masked)] > 0)


@pytest.mark.parametrize("impl", IMPLS)
def test_select_argmax_ties_first_occurrence(impl):
    # constant rows: every column ties; argmax semantics pick column 0
    h = jnp.zeros((16, 8))
    w = jnp.zeros((8, 700))
    masked = jnp.ones((16,), bool)
    _check(h, w, masked, None, impl)
    c, _ = fused_select(h, w, masked, impl=impl, interpret=True)
    assert np.all(np.asarray(c) == 0)
    # a dominant column duplicated across tile boundaries: both paths must
    # agree on the earlier index (cross-tile tie-break)
    h, w, masked = _inputs(32, 16, 1200, key=3)
    h = jnp.abs(h)
    col = jnp.full((16,), 5.0)
    w = w.at[:, 37].set(col).at[:, 1100].set(col)
    rc, _ = select_ref(h, w, masked)
    c, _ = fused_select(h, w, masked, impl=impl, interpret=True)
    assert np.all(np.asarray(rc) == 37)
    assert np.array_equal(np.asarray(c), np.asarray(rc))


@pytest.mark.parametrize("impl", IMPLS)
def test_select_fully_finalized_rows(impl):
    """A block whose every position is already finalized: all confidences
    -inf (never re-selected), candidates still the argmax."""
    h, w, _ = _inputs(64, 32, 512, key=11)
    masked = jnp.zeros((64,), bool)
    rc, _ = select_ref(h, w, masked)
    c, f = fused_select(h, w, masked, impl=impl, interpret=True)
    assert np.all(np.isneginf(np.asarray(f)))
    assert np.array_equal(np.asarray(c), np.asarray(rc))


def test_select_unknown_impl_raises():
    h, w, masked = _inputs(8, 16, 100)
    with pytest.raises(ValueError, match="impl"):
        fused_select(h, w, masked, impl="bogus")


# ---------------------------------------------------------------------------
# Against the baseline diffusion path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", IMPLS)
def test_fused_entry_matches_confidence_and_candidates(impl):
    """confidence_and_candidates_fused(hidden, w, ...) == the baseline
    lm_head -> softmax path, at model layout (b, L, d)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, L, d, V = 2, 12, 32, 593
    hidden = jax.random.normal(ks[0], (b, L, d)) * 0.5
    w = jax.random.normal(ks[1], (d, V)) * 0.1
    tokens = jax.random.randint(ks[2], (b, L), 0, V)
    tokens = tokens.at[:, ::3].set(V - 1)  # some masked positions
    for cap in (None, 30.0):
        logits = jnp.einsum("bld,dv->blv", hidden, w,
                            preferred_element_type=jnp.float32)
        if cap is not None:
            logits = cap * jnp.tanh(logits / cap)
        rc, rf = D.confidence_and_candidates(logits, tokens, V - 1)
        c, f = D.confidence_and_candidates_fused(
            hidden, w, tokens, V - 1, softcap=cap, impl=impl, interpret=True)
        assert np.array_equal(np.asarray(c), np.asarray(rc))
        assert np.array_equal(np.isneginf(np.asarray(f)),
                              np.isneginf(np.asarray(rf)))
        finite = np.isfinite(np.asarray(rf))
        assert float(np.abs(np.asarray(f)[finite]
                            - np.asarray(rf)[finite]).max()) < 1e-6


def test_fused_entry_sampled_fallback_is_rng_bit_compatible():
    """temperature > 0: the fused entry point computes dense logits and
    reuses the baseline categorical — identical draws, bit for bit."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    b, L, d, V = 2, 8, 16, 128
    hidden = jax.random.normal(ks[0], (b, L, d))
    w = jax.random.normal(ks[1], (d, V)) * 0.2
    tokens = jnp.full((b, L), V - 1)
    logits = jnp.einsum("bld,dv->blv", hidden, w,
                        preferred_element_type=jnp.float32)
    key = jax.random.PRNGKey(42)
    rc, rf = D.confidence_and_candidates(logits, tokens, V - 1, 0.7, key)
    c, f = D.confidence_and_candidates_fused(hidden, w, tokens, V - 1, 0.7,
                                             key)
    assert np.array_equal(np.asarray(c), np.asarray(rc))
    assert np.array_equal(np.asarray(f), np.asarray(rf))


# ---------------------------------------------------------------------------
# End-to-end: fused decode is token-identical, and materializes no logits
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    params = init_model(jax.random.PRNGKey(0), CFG)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, P), 2,
                                 CFG.vocab_size)
    return params, prompts


def _spec(**kw):
    return SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                       conf_threshold=0.5, **kw)


@pytest.mark.parametrize("name", ["cdlm", "fast_dllm", "vanilla"])
def test_fused_decode_token_identical(setup, name):
    """A full decode with --fused-select produces the same tokens, steps
    and call counts as the baseline logits path (temperature 0)."""
    params, prompts = setup
    key = jax.random.PRNGKey(42)
    base = SAMPLERS[name](params, prompts, cfg=CFG, spec=_spec(), key=key)
    fused = SAMPLERS[name](params, prompts, cfg=CFG,
                           spec=_spec(fused_select=True), key=key)
    assert np.array_equal(np.asarray(base.tokens), np.asarray(fused.tokens))
    assert np.array_equal(np.asarray(base.steps), np.asarray(fused.steps))
    assert int(base.n_model_calls) == int(fused.n_model_calls)
    assert np.array_equal(np.asarray(base.gen_lengths),
                          np.asarray(fused.gen_lengths))


def test_fused_decode_token_identical_with_final_softcap():
    """gemma2-style final-logit softcap + tied embeddings through a full
    fused cdlm decode."""
    cfg = get_config("gemma2-27b").reduced(dtype="float32")
    assert cfg.final_logit_softcap is not None and cfg.tie_embeddings
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, P), 2,
                                 cfg.vocab_size - 1)
    key = jax.random.PRNGKey(7)
    base = SAMPLERS["cdlm"](params, prompts, cfg=cfg, spec=_spec(), key=key)
    fused = SAMPLERS["cdlm"](params, prompts, cfg=cfg,
                             spec=_spec(fused_select=True), key=key)
    assert np.array_equal(np.asarray(base.tokens), np.asarray(fused.tokens))
    assert np.array_equal(np.asarray(base.steps), np.asarray(fused.steps))


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            yield from _iter_params(v)


def _iter_params(v):
    closed = getattr(v, "jaxpr", None)
    if closed is not None and hasattr(closed, "eqns"):
        yield from _iter_eqns(closed)
    elif hasattr(v, "eqns"):
        yield from _iter_eqns(v)
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_params(x)


def _vocab_cube_count(fn, *args, vocab):
    """Number of intermediates shaped (..., ≥3 dims, last == vocab) anywhere
    in the traced jaxpr, sub-jaxprs (while/cond/scan/pallas) included."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    hits = 0
    for eqn in _iter_eqns(jaxpr.jaxpr):
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            if len(shape) >= 3 and shape and shape[-1] == vocab:
                hits += 1
    return hits


def test_fused_decode_materializes_no_logits():
    """Structural guarantee: the fused cdlm decode's jaxpr contains no
    (b, ·, V) tensor — neither block logits nor a (b, T, V) canvas. The
    same detector must fire on the baseline path (sanity of the check).
    Uses a config whose vocab size matches no other model dimension, so a
    (…, V)-shaped hit can only be a logits tensor."""
    vcfg = get_config("qwen2-0.5b").reduced(dtype="float32", vocab_size=384,
                                            mask_token_id=383)
    assert vcfg.vocab_size not in (vcfg.d_model, vcfg.d_ff, vcfg.head_dim)
    params = init_model(jax.random.PRNGKey(0), vcfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, P), 2,
                                 vcfg.vocab_size - 1)
    key = jax.random.PRNGKey(0)

    def run(spec):
        return lambda p, t, k: SAMPLERS["cdlm"](p, t, cfg=vcfg, spec=spec,
                                                key=k).tokens

    assert _vocab_cube_count(run(_spec()), params, prompts, key,
                             vocab=vcfg.vocab_size) > 0
    assert _vocab_cube_count(run(_spec(fused_select=True)), params, prompts,
                             key, vocab=vcfg.vocab_size) == 0


def test_continuous_engine_fused_select_identical(setup):
    """ContinuousEngine with fused_select serves bit-identical responses."""
    params, _ = setup
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(2, CFG.vocab_size, P,
                                        dtype=np.int32), id=i)
            for i in range(3)]

    def serve(fused):
        return ServeConfig(max_batch=2, block_size=B, gen_length=G,
                           sampler="cdlm", conf_threshold=0.5,
                           scheduler="continuous", fused_select=fused)

    outs = {}
    for fused in (False, True):
        eng = ContinuousEngine(params, CFG, serve(fused), prompt_len=P)
        outs[fused] = {r.id: r for r in eng.generate(list(reqs))}
    assert outs[False].keys() == outs[True].keys()
    for rid, base in outs[False].items():
        got = outs[True][rid]
        assert np.array_equal(base.tokens, got.tokens), rid
        assert base.steps == got.steps and base.gen_length == got.gen_length
