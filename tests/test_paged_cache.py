"""Dense/paged cache-layout equivalence suite.

THE layout invariant: the block-paged cache is a pure memory-layout change —
logits, tokens, steps, and cache commits are *bit-identical* to the dense
layout, from a single cached block decode up through the continuous serving
engine (including page-starved scheduling: stalls and preemptions)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig
from repro.configs.registry import get_config
from repro.core import cache as C
from repro.core import masks
from repro.core.block_loop import STRATEGIES, SamplerSpec, run_block_loop
from repro.models import forward, init_model
from repro.serving import ContinuousEngine, Request

CFG = get_config("qwen2-0.5b").reduced(dtype="float32")
P, G, B = 8, 16, 4
T = P + G


@pytest.fixture(scope="module")
def params():
    return init_model(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def requests():
    rng = np.random.default_rng(0)
    return [Request(prompt=rng.integers(2, CFG.vocab_size, P,
                                        dtype=np.int32), id=i)
            for i in range(5)]


def _serve(max_batch=2, **kw):
    return ServeConfig(max_batch=max_batch, block_size=B, gen_length=G,
                       sampler="cdlm", conf_threshold=0.5,
                       scheduler="continuous", **kw)


@pytest.fixture(scope="module")
def dense_responses(params, requests):
    eng = ContinuousEngine(params, CFG, _serve(), prompt_len=P)
    eng.warmup()
    return {r.id: r for r in eng.generate(requests)}


def _assert_same_responses(ref, got):
    assert sorted(got) == sorted(ref)
    for i in ref:
        assert np.array_equal(ref[i].tokens, got[i].tokens), i
        assert ref[i].steps == got[i].steps, i
        assert ref[i].gen_length == got[i].gen_length, i


# ---------------------------------------------------------------------------
# Forward / block-loop equivalence
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma2-27b",
                                  "kimi-k2-1t-a32b"])
def test_cached_block_decode_paged_bitwise(arch):
    """A cached block decode through the paged gather path is bit-identical
    to the dense cache — softcap/SWA (gemma2) and MoE (kimi) included."""
    cfg = get_config(arch).reduced(dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    b = 2
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, T), 2,
                                cfg.vocab_size)
    out = forward(params, tokens[:, :P], cfg=cfg, mode=masks.BLOCK_CAUSAL,
                  prompt_len=P, block_size=B, moe_dropless=True)
    rows = jnp.ones((b,), bool)
    dense = C.commit_rows(C.init_cache(cfg, b, T, dtype="float32"),
                          out.emissions, 0, rows)
    paged = C.init_paged_cache(cfg, b, T, n_pages=b * (T // B), page_size=B,
                               dtype="float32")
    paged, _ = C.alloc(paged, rows, 0, T)
    paged = C.commit_rows(paged, out.emissions, 0, rows)

    kw = dict(cfg=cfg, mode=masks.BLOCK_CAUSAL, prompt_len=P, block_size=B,
              positions=P + jnp.arange(B), cache_len=P)
    want = forward(params, tokens[:, P:P + B], cache=dense, **kw)
    got = forward(params, tokens[:, P:P + B], cache=paged, **kw)
    assert np.array_equal(np.asarray(want.logits), np.asarray(got.logits))


def test_run_block_loop_paged_equals_dense(params):
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                       conf_threshold=0.5)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, P), 2,
                                 CFG.vocab_size)
    key = jax.random.PRNGKey(2)
    want = run_block_loop(params, prompts, cfg=CFG, spec=spec,
                          strategy=STRATEGIES["cdlm"], key=key)
    spec_p = dataclasses.replace(spec, cache_layout="paged")
    got = jax.jit(
        lambda p, x, k: run_block_loop(p, x, cfg=CFG, spec=spec_p,
                                       strategy=STRATEGIES["cdlm"], key=k)
    )(params, prompts, key)
    assert np.array_equal(np.asarray(want.tokens), np.asarray(got.tokens))
    assert np.array_equal(np.asarray(want.steps), np.asarray(got.steps))
    assert int(want.n_model_calls) == int(got.n_model_calls)


def test_paged_requires_exact_commit(params):
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                       cache_layout="paged")
    prompts = jnp.zeros((1, P), jnp.int32)
    with pytest.raises(ValueError, match="exact-commit"):
        run_block_loop(params, prompts, cfg=CFG, spec=spec,
                       strategy=STRATEGIES["fast_dllm"])


# ---------------------------------------------------------------------------
# Continuous-engine equivalence across pool pressure
# ---------------------------------------------------------------------------
def test_engine_paged_equals_dense(params, requests, dense_responses):
    eng = ContinuousEngine(params, CFG, _serve(cache_layout="paged"),
                           prompt_len=P)
    eng.warmup()
    _assert_same_responses(dense_responses,
                           {r.id: r for r in eng.generate(requests)})
    stats = eng.page_pool_stats()
    assert stats["n_pages"] == 2 * (T // B)
    assert 0 < stats["peak_pages"] <= stats["n_pages"]


def test_engine_minimum_pool_exact_with_page_reuse(params, requests,
                                                   dense_responses):
    """A pool holding exactly ONE full canvas: optimistic admission still
    lets a second lane in (prompt + next block fit), so requests constantly
    contend for pages and recycle them — outputs must still be
    bit-identical."""
    eng = ContinuousEngine(
        params, CFG, _serve(cache_layout="paged", page_pool_pages=T // B),
        prompt_len=P)
    eng.warmup()
    _assert_same_responses(dense_responses,
                           {r.id: r for r in eng.generate(requests)})
    stats = eng.page_pool_stats()
    assert stats["peak_occupancy"] == 1.0
    assert stats["preemptions"] + stats["stall_rounds"] > 0


def test_engine_tight_pool_exact_under_preemption(params, requests,
                                                  dense_responses):
    """A pool too small for two full canvases forces stalls/preemptions;
    preempted requests re-decode from scratch — still loss-free."""
    eng = ContinuousEngine(
        params, CFG,
        _serve(cache_layout="paged", page_pool_pages=T // B + 2),
        prompt_len=P)
    eng.warmup()
    _assert_same_responses(dense_responses,
                           {r.id: r for r in eng.generate(requests)})
    stats = eng.page_pool_stats()
    assert stats["preemptions"] + stats["stall_rounds"] > 0


def test_engine_mixed_max_tokens_paged(params, requests, dense_responses):
    """Mixed generation caps through a tight pool: short requests free pages
    early; every request still matches its solo decode."""
    eng = ContinuousEngine(
        params, CFG,
        _serve(cache_layout="paged", page_pool_pages=T // B + 2),
        prompt_len=P)
    eng.warmup()
    mixed = [Request(prompt=r.prompt, id=r.id,
                     max_tokens=B if r.id < 2 else None) for r in requests]
    got = {r.id: r for r in eng.generate(mixed)}
    for req in mixed:
        solo = eng.generate([Request(prompt=req.prompt, id=req.id,
                                     max_tokens=req.max_tokens)])[0]
        assert np.array_equal(solo.tokens, got[req.id].tokens), req.id
        assert solo.steps == got[req.id].steps, req.id


def test_engine_paged_kernel_path(params, requests, dense_responses):
    """use_paged_kernel=True routes decode through the Pallas page-table
    kernel (interpret mode on CPU). Not bit-equal to the gather path
    (reduction order differs) but the toy fixture's confidences sit far
    from the threshold, so tokens/steps must still match."""
    eng = ContinuousEngine(params, CFG, _serve(cache_layout="paged"),
                           prompt_len=P, use_paged_kernel=True)
    eng.warmup()
    _assert_same_responses(dense_responses,
                           {r.id: r for r in eng.generate(requests)})


def test_paged_kernel_requires_paged_layout(params):
    with pytest.raises(ValueError, match="use_paged_kernel"):
        ContinuousEngine(params, CFG, _serve(), prompt_len=P,
                         use_paged_kernel=True)


def test_static_engine_rejects_pool_sizing(params):
    from repro.serving import Engine
    serve = ServeConfig(max_batch=2, block_size=B, gen_length=G,
                        sampler="cdlm", scheduler="static",
                        cache_layout="paged", page_pool_pages=6)
    with pytest.raises(ValueError, match="page_pool_pages"):
        Engine(params, CFG, serve, prompt_len=P)


def test_pool_undersized_raises(params):
    with pytest.raises(ValueError, match="deadlock-free minimum"):
        ContinuousEngine(
            params, CFG,
            _serve(cache_layout="paged", page_pool_pages=T // B - 1),
            prompt_len=P)


def test_unknown_layout_raises(params):
    with pytest.raises(ValueError, match="cache layout"):
        ContinuousEngine(params, CFG, _serve(cache_layout="bogus"),
                         prompt_len=P)


def test_dense_layout_rejects_pool_sizing(params):
    """page_pool_pages with the dense layout would be silently ignored —
    reject it so memory-budget comparisons can't be misconfigured."""
    with pytest.raises(ValueError, match="page_pool_pages"):
        ContinuousEngine(params, CFG, _serve(page_pool_pages=12),
                         prompt_len=P)
