"""Kernel tuned-config registry (repro.kernels.tuning) + bench trajectory.

Covers the PR contracts:

- every entry in the checked-in tuned table produces outputs equivalent to
  the op's built-in default config *and* its dense reference oracle
  (candidate ids exact; confidences/losses to fp tolerance — a different
  vocab chunk changes fp32 reduction order by design);
- registry lookups fall back cleanly on unknown buckets/backends/ops;
- resolution precedence: explicit legacy kwarg > config field > tuned
  table > built-in default;
- the paged engine's host page-accounting mirror equals the device
  allocator's free-page count at every block boundary (the sync-free
  scheduling invariant);
- the bench trajectory gate passes/fails per tracked-metric tolerance.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tuning
from repro.kernels.select import fused_select, select_ref
from repro.kernels.xent import fused_xent

# benchmarks.* lives at the repo root, not under src/
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# KernelConfig + registry mechanics
# ---------------------------------------------------------------------------
def test_kernel_config_hashable_and_roundtrips():
    cfg = tuning.KernelConfig(block_t=64, chunk=1024, impl="streaming")
    assert hash(cfg) == hash(tuning.KernelConfig(**cfg.to_dict()))
    assert tuning.KernelConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError, match="unknown KernelConfig fields"):
        tuning.KernelConfig.from_dict({"block_z": 1})


def test_buckets_are_pow2_and_op_specific():
    assert tuning.bucket_for("select", V=32_768) == "V32768"
    assert tuning.bucket_for("select", V=50_000) == "V65536"
    assert tuning.bucket_for("xent", V=131_072) == "V131072"
    assert tuning.bucket_for("decode_attn", S=1000) == "S1024"
    assert tuning.bucket_for("block_attn", L=512) == "L512"
    with pytest.raises(ValueError, match="unknown op"):
        tuning.bucket_for("nope", V=1)


def test_lookup_falls_back_cleanly(tmp_path):
    """Unknown buckets/backends/ops resolve to None (lookup) and to the
    op's built-in defaults (resolve) — the table is never load-bearing."""
    p = tmp_path / "table.json"
    p.write_text(json.dumps({"version": 1, "entries": [
        {"op": "select", "bucket": "V32768", "backend": "cpu",
         "config": {"impl": "streaming", "chunk": 4096}},
    ]}))
    path = str(p)
    assert tuning.lookup("select", "V32768", backend_name="cpu",
                         path=path).chunk == 4096
    assert tuning.lookup("select", "V1024", backend_name="cpu",
                         path=path) is None          # unknown bucket
    assert tuning.lookup("select", "V32768", backend_name="tpu",
                         path=path) is None          # unknown backend
    assert tuning.lookup("xent", "V32768", backend_name="cpu",
                         path=path) is None          # op not in table
    # resolve() on a table miss == the op's built-in defaults
    missing = tuning.resolve("select", table_path=str(tmp_path / "no.json"),
                             V=32_768)
    assert missing == tuning.OP_DEFAULTS["select"]
    with pytest.raises(ValueError, match="unknown op"):
        tuning.resolve("nope", V=1)


def test_resolution_precedence(tmp_path):
    p = tmp_path / "table.json"
    p.write_text(json.dumps({"version": 1, "entries": [
        {"op": "select", "bucket": tuning.bucket_for("select", V=4096),
         "backend": tuning.backend(),
         "config": {"impl": "streaming", "chunk": 2048, "block_t": 32}},
    ]}))
    path = str(p)
    # tuned table beats built-in default
    cfg = tuning.resolve("select", V=4096, table_path=path)
    assert (cfg.chunk, cfg.block_t) == (2048, 32)
    assert cfg.block_v == 512  # untouched knob keeps the built-in default
    # config field beats table
    cfg = tuning.resolve("select", V=4096, table_path=path,
                         config=tuning.KernelConfig(chunk=512))
    assert cfg.chunk == 512 and cfg.block_t == 32
    # explicit legacy kwarg beats config field (merge_legacy layering)
    merged = tuning.merge_legacy(tuning.KernelConfig(chunk=512, block_t=8),
                                 block_t=16)
    cfg = tuning.resolve("select", V=4096, table_path=path, config=merged)
    assert cfg.block_t == 16 and cfg.chunk == 512
    # merge_legacy with nothing explicit is a pure passthrough
    assert tuning.merge_legacy(None) is None
    assert tuning.merge_legacy(None, block_t=None) is None


def test_save_table_merges_preserving_other_backends(tmp_path):
    path = str(tmp_path / "t.json")
    tuning.save_table([{"op": "select", "bucket": "V1024", "backend": "tpu",
                        "config": {"block_v": 1024}}], path)
    tuning.save_table([{"op": "select", "bucket": "V1024", "backend": "cpu",
                        "config": {"chunk": 512}}], path)
    assert tuning.lookup("select", "V1024", backend_name="tpu",
                         path=path).block_v == 1024
    assert tuning.lookup("select", "V1024", backend_name="cpu",
                         path=path).chunk == 512


# ---------------------------------------------------------------------------
# Checked-in table entries: tuned config == default config == oracle
# ---------------------------------------------------------------------------
def _table_entries():
    with open(tuning.TABLE_PATH) as f:
        return json.load(f)["entries"]


def test_checked_in_table_is_loadable():
    entries = _table_entries()
    assert entries, "tuned_configs.json must ship at least one entry"
    for e in entries:
        assert e["op"] in tuning.OP_DEFAULTS
        tuning.KernelConfig.from_dict(e["config"])  # schema-valid


@pytest.mark.parametrize("entry", _table_entries(),
                         ids=lambda e: f"{e['op']}-{e['bucket']}")
def test_tuned_config_matches_default_and_oracle(entry):
    """Every shipped tuned config produces the same results as the op's
    built-in default config and its dense reference. Candidate ids are
    bit-identical; probabilities/losses/grads match to fp32 tolerance (a
    tuned vocab chunk legitimately changes fp32 reduction order). Shapes
    are CI-trimmed — bucketing/resolution math is shape-independent."""
    op = entry["op"]
    cfg = tuning.KernelConfig.from_dict(entry["config"])
    default = tuning.OP_DEFAULTS[op]
    key = jax.random.PRNGKey(0)
    T, d, V = 16, 32, 4096
    if op == "select":
        ks = jax.random.split(key, 3)
        h = jax.random.normal(ks[0], (T, d), jnp.float32) * 0.5
        w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
        m = jax.random.bernoulli(ks[2], 0.7, (T,))
        ct, ft = fused_select(h, w, m, config=cfg)
        cd, fd = fused_select(h, w, m, config=default)
        cr, fr = select_ref(h, w, m)
        np.testing.assert_array_equal(np.asarray(ct), np.asarray(cd))
        np.testing.assert_array_equal(np.asarray(ct), np.asarray(cr))
        np.testing.assert_allclose(np.asarray(ft), np.asarray(fd),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ft), np.asarray(fr),
                                   rtol=1e-5, atol=1e-6)
    elif op == "xent":
        ks = jax.random.split(key, 3)
        h = jax.random.normal(ks[0], (T, d), jnp.float32) * 0.5
        w = jax.random.normal(ks[1], (d, V), jnp.float32) * 0.1
        y = jax.random.randint(ks[2], (T,), 0, V)
        ref = -jax.nn.log_softmax(h.astype(jnp.float32) @ w)[
            jnp.arange(T), y]
        lt = fused_xent(h, w, y, config=cfg)
        ld = fused_xent(h, w, y, config=default)
        np.testing.assert_allclose(np.asarray(lt), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(lt), np.asarray(ld),
                                   rtol=1e-5, atol=1e-6)
        gt = jax.grad(lambda h: fused_xent(h, w, y, config=cfg).sum())(h)
        gd = jax.grad(
            lambda h: fused_xent(h, w, y, config=default).sum())(h)
        np.testing.assert_allclose(np.asarray(gt), np.asarray(gd),
                                   rtol=1e-4, atol=1e-5)
    elif op == "decode_attn":
        from repro.kernels.decode_attn import decode_attention
        b, Bq, Kv, G, hd, S = 2, 4, 2, 2, 8, 64
        ks = jax.random.split(key, 5)
        q = jax.random.normal(ks[0], (b, Bq, Kv, G, hd))
        kc = jax.random.normal(ks[1], (b, S, Kv, hd))
        vc = jax.random.normal(ks[2], (b, S, Kv, hd))
        kb = jax.random.normal(ks[3], (b, Bq, Kv, hd))
        vb = jax.random.normal(ks[4], (b, Bq, Kv, hd))
        clen = jnp.asarray(S, jnp.int32)
        ot = decode_attention(q, kc, vc, kb, vb, clen, scale=0.125,
                              config=cfg)
        od = decode_attention(q, kc, vc, kb, vb, clen, scale=0.125,
                              config=default)
        np.testing.assert_allclose(np.asarray(ot), np.asarray(od),
                                   rtol=1e-5, atol=1e-6)
    else:  # block_attn
        from repro.kernels.block_attn import flash_block_attention
        b, L, Kv, G, hd = 1, 64, 2, 2, 8
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, L, Kv, G, hd))
        k = jax.random.normal(ks[1], (b, L, Kv, hd))
        v = jax.random.normal(ks[2], (b, L, Kv, hd))
        ot = flash_block_attention(q, k, v, prompt_len=16, block_size=16,
                                   scale=0.125, config=cfg)
        od = flash_block_attention(q, k, v, prompt_len=16, block_size=16,
                                   scale=0.125, config=default)
        np.testing.assert_allclose(np.asarray(ot), np.asarray(od),
                                   rtol=1e-5, atol=1e-6)


def test_legacy_kwargs_still_work_and_win():
    """Deprecated per-knob kwargs keep working and match the config path."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    h = jax.random.normal(ks[0], (8, 16))
    w = jax.random.normal(ks[1], (16, 64))
    m = jax.random.bernoulli(ks[2], 0.7, (8,))
    cr, fr = select_ref(h, w, m)
    for kwargs in ({"impl": "streaming"},
                   {"impl": "pallas", "interpret": True,
                    "block_t": 8, "block_v": 32}):
        c, f = fused_select(h, w, m, **kwargs)
        np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
        np.testing.assert_allclose(np.asarray(f), np.asarray(fr),
                                   rtol=1e-5, atol=1e-6)
    # legacy kwarg == the same knob via config=
    ck, fk = fused_select(h, w, m, impl="streaming", block_v=32)
    cc, fc = fused_select(
        h, w, m, config=tuning.KernelConfig(impl="streaming", block_v=32))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cc))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(fc))
    with pytest.raises(ValueError, match="unknown fused_select impl"):
        fused_select(h, w, m, impl="bogus")


# ---------------------------------------------------------------------------
# Paged engine: host page-accounting mirror == device allocator
# ---------------------------------------------------------------------------
def test_paged_engine_host_mirror_matches_device():
    """The sync-free scheduler's host mirror must equal the device pool's
    free-page count at every block boundary — including under stalls and
    preemptions (tight pool) and mixed max_tokens — and end fully free
    after the drain."""
    from repro.configs.base import ServeConfig
    from repro.configs.registry import get_config
    from repro.models import init_model
    from repro.serving import ContinuousEngine, Request

    cfg = get_config("qwen2-0.5b").reduced(
        n_layers=2, d_model=128, d_ff=256, vocab_size=128,
        mask_token_id=127)
    P, G, B = 8, 16, 4
    T = P + G
    serve = ServeConfig(max_batch=2, block_size=B, gen_length=G,
                        sampler="cdlm", conf_threshold=0.5,
                        scheduler="continuous", cache_layout="paged",
                        page_pool_pages=T // B + 2)  # tight: forces stalls
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ContinuousEngine(params, cfg, serve, prompt_len=P)
    eng.warmup()
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.add_request(Request(
            prompt=rng.integers(2, 120, P).astype(np.int32), id=i,
            max_tokens=B if i % 2 else None))
    done = 0
    while eng.has_unfinished():
        done += sum(ev.finished for ev in eng.step())
        host_free, dev_free = eng.page_accounting()
        assert host_free == dev_free, \
            f"host mirror {host_free} != device {dev_free}"
    assert done == 5
    host_free, dev_free = eng.page_accounting()
    assert host_free == dev_free == eng.n_pages


# ---------------------------------------------------------------------------
# Bench trajectory gate
# ---------------------------------------------------------------------------
def _trajectory():
    from benchmarks import trajectory
    return trajectory


def test_trajectory_gate_passes_within_tolerance():
    tr = _trajectory()
    prev = {"metrics": {"select_speedup_V32768": 1.30,
                        "paged_stall_rounds": 1.0}}
    cand = {"metrics": {"select_speedup_V32768": 1.20,   # -7.7% < 10%
                        "paged_stall_rounds": 3.0}}      # +2 == abs slack
    assert tr.gate(cand, prev) == []
    assert tr.gate(cand, None) == []                     # first run passes
    assert tr.gate({"metrics": {}}, prev) == []          # missing metric ok


def test_trajectory_gate_fails_beyond_tolerance():
    tr = _trajectory()
    prev = {"metrics": {"select_speedup_V32768": 1.30,
                        "paged_stall_rounds": 1.0}}
    fails = tr.gate({"metrics": {"select_speedup_V32768": 1.10}}, prev)
    assert len(fails) == 1 and "select_speedup_V32768" in fails[0]
    fails = tr.gate({"metrics": {"paged_stall_rounds": 4.0}}, prev)
    assert len(fails) == 1 and "paged_stall_rounds" in fails[0]


def test_trajectory_append_and_gate_roundtrip(tmp_path):
    tr = _trajectory()
    path = str(tmp_path / "traj.jsonl")
    kernels = {"smoke": True,
               "select": {"V32768": {"speedup": 1.25}},
               "records": [{"op": "select", "shape": {"V": 32768},
                            "backend": "cpu", "metric": "speedup_vs_dense",
                            "value": 1.25, "config": {}}]}
    serving = {"smoke": True,
               "schedulers": {"speedup": 0.9},
               "layouts": {"concurrency_gain": 1.33,
                           "dense": {"tps": 100.0},
                           "paged": {"tps": 90.0,
                                     "pool": {"stall_rounds": 0.0}}}}
    kp, sp = tmp_path / "k.json", tmp_path / "s.json"
    kp.write_text(json.dumps(kernels))
    sp.write_text(json.dumps(serving))
    run = tr.build_run(str(kp), str(sp))
    assert run["metrics"]["select_speedup_V32768"] == 1.25
    assert run["metrics"]["continuous_static_speedup"] == 0.9
    assert run["metrics"]["paged_dense_tps_ratio"] == pytest.approx(0.9)
    assert run["metrics"]["paged_stall_rounds"] == 0.0
    assert run["metrics"]["paged_concurrency_gain"] == 1.33
    tr.append_run(path, run)
    runs = tr.load_runs(path)
    assert len(runs) == 1
    assert tr.gate(run, runs[-1]) == []      # identical run: clean pass
    worse = {"metrics": dict(run["metrics"], select_speedup_V32768=1.0)}
    assert tr.gate(worse, runs[-1])          # >10% drop: fails
    # CLI surface: gate exits 0 on pass, 1 on regression
    assert tr.main(["gate", "--trajectory", path,
                    "--kernels", str(kp), "--serving", str(sp)]) == 0
    kernels["select"]["V32768"]["speedup"] = 1.0
    kp.write_text(json.dumps(kernels))
    assert tr.main(["gate", "--trajectory", path,
                    "--kernels", str(kp), "--serving", str(sp)]) == 1


def test_shared_record_schema():
    from benchmarks import common
    r = common.record("select", {"V": 1024}, "us_per_call", 12.5,
                      backend="cpu", config={"chunk": 512})
    assert set(r) == {"op", "shape", "backend", "metric", "value", "config"}
    assert r["value"] == 12.5 and r["shape"] == {"V": 1024}
