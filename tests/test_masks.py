"""Property tests for the attention-visibility builders (paper Fig. 2)."""
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import masks


@st.composite
def mask_geometry(draw):
    block = draw(st.integers(1, 8))
    n_blocks = draw(st.integers(1, 6))
    prompt = draw(st.integers(0, 12))
    return prompt, block, prompt + block * n_blocks


@settings(max_examples=50, deadline=None)
@given(mask_geometry())
def test_block_causal_properties(geom):
    prompt, B, total = geom
    vis = np.asarray(masks.visible(
        np.arange(total), np.arange(total), mode=masks.BLOCK_CAUSAL,
        prompt_len=prompt, block_size=B))
    blk = np.asarray(masks.block_index(np.arange(total), prompt, B))
    for qi in range(total):
        for ki in range(total):
            assert vis[qi, ki] == (blk[ki] <= blk[qi])
    # prompt is fully bidirectional within itself
    if prompt:
        assert vis[:prompt, :prompt].all()
    # every position sees the prompt
    if prompt:
        assert vis[:, :prompt].all()
    # within-block bidirectionality
    for b in range((total - prompt) // B):
        s = prompt + b * B
        assert vis[s:s + B, s:s + B].all()
    # no peeking at future blocks
    for qi in range(prompt, total):
        qb = blk[qi]
        nxt = prompt + (qb + 1) * B
        if nxt < total:
            assert not vis[qi, nxt:].any()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 40), st.integers(1, 16))
def test_causal_window(total, window):
    vis = np.asarray(masks.visible(np.arange(total), np.arange(total),
                                   mode=masks.CAUSAL, window=window))
    q, k = np.meshgrid(np.arange(total), np.arange(total), indexing="ij")
    expect = (k <= q) & (q - k < window)
    assert (vis == expect).all()


def test_block_causal_is_between_causal_and_bidirectional():
    total, prompt, B = 40, 8, 4
    pos = np.arange(total)
    bc = np.asarray(masks.visible(pos, pos, mode=masks.BLOCK_CAUSAL,
                                  prompt_len=prompt, block_size=B))
    ca = np.asarray(masks.visible(pos, pos, mode=masks.CAUSAL))
    bi = np.asarray(masks.visible(pos, pos, mode=masks.BIDIRECTIONAL))
    assert (ca <= bc).all() and (bc <= bi).all()
    assert bc.sum() > ca.sum() and bc.sum() < bi.sum()


def test_bias_values():
    bias = masks.full_bias(6, mode=masks.CAUSAL)
    assert float(bias[3, 2]) == 0.0
    assert float(bias[2, 3]) < -1e29


def test_bias_fn_kv_valid():
    f = masks.make_bias_fn(mode=masks.BIDIRECTIONAL, kv_valid_len=3)
    b = np.asarray(f(np.arange(2), np.arange(5)))
    assert (b[:, :3] == 0).all() and (b[:, 3:] == masks.NEG_INF).all()
