"""Recurrent-backbone numerics: chunked parallel forms == step-by-step
recurrence (the property that makes their O(1) decode caches exact)."""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import mamba as M
from repro.models import rwkv6 as R


def test_mamba_chunked_equals_stepwise():
    cfg = get_config("jamba-v0.1-52b").reduced(dtype="float32", d_model=64)
    params = M.init_mamba(jax.random.PRNGKey(0), cfg)
    b, L = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (b, L, cfg.d_model)) * 0.5
    full, st_full = M.mamba_forward(params, x, cfg, chunk=8, remat=False)
    # token-by-token with carried state
    st = None
    outs = []
    for t in range(L):
        y, st = M.mamba_forward(params, x[:, t:t + 1], cfg, state=st,
                                chunk=1, remat=False)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - step))) < 1e-4
    assert float(jnp.max(jnp.abs(st_full["ssm"] - st["ssm"]))) < 1e-4
    assert float(jnp.max(jnp.abs(st_full["conv"] - st["conv"]))) < 1e-5


def test_mamba_chunk_size_invariance():
    cfg = get_config("jamba-v0.1-52b").reduced(dtype="float32", d_model=64)
    params = M.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model)) * 0.5
    o1, _ = M.mamba_forward(params, x, cfg, chunk=4, remat=False)
    o2, _ = M.mamba_forward(params, x, cfg, chunk=32, remat=False)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-4


def test_rwkv_time_mix_chunked_equals_stepwise():
    cfg = get_config("rwkv6-1.6b").reduced(dtype="float32", d_model=128)
    params = R.init_time_mix(jax.random.PRNGKey(0), cfg)
    b, L = 2, 20
    x = jax.random.normal(jax.random.PRNGKey(1), (b, L, cfg.d_model)) * 0.5
    st0 = R.init_rwkv_state(cfg, b)
    full, st_full = R.time_mix(params, x, cfg, st0, chunk=8, remat=False)
    st = {"S": st0["S"], "tm_shift": st0["tm_shift"],
          "cm_shift": st0["cm_shift"]}
    outs = []
    for t in range(L):
        y, new = R.time_mix(params, x[:, t:t + 1], cfg, st, chunk=1,
                            remat=False)
        st = {**st, **new}
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - step))) < 1e-4
    assert float(jnp.max(jnp.abs(st_full["S"] - st["S"]))) < 1e-3


def test_rwkv_decay_in_unit_interval():
    cfg = get_config("rwkv6-1.6b").reduced(dtype="float32", d_model=128)
    params = R.init_time_mix(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    prev = jnp.concatenate([jnp.zeros((1, 1, cfg.d_model)), x[:, :-1]], 1)
    xw = x + (prev - x) * params["mu_w"]
    decay = jnp.exp(-jnp.exp(params["w0"] + jnp.tanh(xw @ params["wa"]) @ params["wb"]))
    assert bool((decay > 0).all()) and bool((decay < 1).all())


def test_rwkv_channel_mix_token_shift():
    cfg = get_config("rwkv6-1.6b").reduced(dtype="float32", d_model=64)
    params = R.init_channel_mix(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, cfg.d_model))
    st = R.init_rwkv_state(cfg, 1)
    full, _ = R.channel_mix(params, x, cfg, st)
    # position t must depend on x[t-1]: perturb x[2], outputs at 2 and 3 move
    x2 = x.at[:, 2].add(1.0)
    pert, _ = R.channel_mix(params, x2, cfg, st)
    d = jnp.abs(full - pert).sum(-1)[0]
    assert float(d[1]) < 1e-6 and float(d[2]) > 1e-6 and float(d[3]) > 1e-6
    assert float(d[4]) < 1e-6  # ...but not beyond one step
