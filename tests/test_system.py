"""End-to-end behaviour tests for the CDLM system.

The flagship test runs the complete paper pipeline at toy scale: pretrain a
bidirectional teacher on the sort task (Eq. 6), collect Alg.-1 trajectories,
distill the block-causal student with the 3-objective Alg. 2, and verify the
paper's core claims hold directionally:

  (1) the student finalizes multiple tokens per step (steps < L_g),
  (2) quality is maintained relative to the teacher,
  (3) naive step truncation of the teacher degrades quality (Table 4).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CDLMConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.sampler import SamplerSpec, cdlm, fast_dllm_parallel, vanilla_blockwise
from repro.data import Corpus, TaskSpec
from repro.data.synthetic import score
from repro.serving import Engine, Request, efficiency_report
from repro.training import trainer

CFG = get_config("qwen2-0.5b").reduced(
    n_layers=2, d_model=128, d_ff=256, vocab_size=128, mask_token_id=127)
TASK = TaskSpec("sort", vocab_size=128, prompt_len=10, gen_len=10,
                sort_k=8, sort_range=24)
CDLM_CFG = CDLMConfig(block_size=5, gen_length=10, prompt_length=10,
                      temperatures=(0.0,))


@pytest.fixture(scope="module")
def pipeline():
    corpus = Corpus(TASK, 768, seed=0)
    tcfg = TrainConfig(learning_rate=2e-3, steps=700, batch_size=64,
                       remat=False)
    teacher = trainer.train_teacher(CFG, corpus, tcfg, verbose=False)
    ds = trainer.collect_dataset(teacher, CFG, CDLM_CFG, corpus,
                                 n_examples=192, batch=64, verbose=False)
    scfg = dataclasses.replace(tcfg, steps=300, learning_rate=5e-4)
    student = trainer.train_student(teacher, ds, CFG, CDLM_CFG, scfg,
                                    verbose=False)
    return corpus, teacher, student


@pytest.mark.slow
def test_paper_pipeline_claims(pipeline):
    corpus, teacher, student = pipeline
    ev = corpus.eval_batch(64)
    prompts = jnp.asarray(ev["prompt"])
    P, G, B = TASK.prompt_len, TASK.gen_len, CDLM_CFG.block_size

    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                       conf_threshold=0.9, early_stop=False)
    res_teacher = jax.jit(lambda p, x: vanilla_blockwise(
        p, x, cfg=CFG, spec=spec))(teacher, prompts)
    res_student = jax.jit(lambda p, x: cdlm(
        p, x, cfg=CFG, spec=spec))(student, prompts)

    s_teacher = score(ev["prompt"], np.asarray(res_teacher.tokens), P, TASK)
    s_student = score(ev["prompt"], np.asarray(res_student.tokens), P, TASK)
    steps_t = float(res_teacher.steps.mean())
    steps_s = float(res_student.steps.mean())
    print(f"teacher: score={s_teacher:.2f} steps={steps_t:.1f} | "
          f"student: score={s_student:.2f} steps={steps_s:.1f}")

    # claim (1): multi-token finalization reduces refinement steps — the
    # structural CDLM effect, robust at any scale
    assert steps_s < 0.8 * steps_t, (steps_s, steps_t)
    # claims (2)/(3) are score-based: exact-match at this toy budget is
    # training-limited (greedy low-confidence remasking cascades on tiny
    # models — EXPERIMENTS.md §Validation caveat). Asserted only when the
    # teacher actually solves the task; otherwise the directional check is
    # that the student is not WORSE than the teacher.
    trunc_spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                             conf_threshold=0.0, early_stop=False)
    res_trunc = jax.jit(lambda p, x: fast_dllm_parallel(
        p, x, cfg=CFG, spec=trunc_spec))(teacher, prompts)
    s_trunc = score(ev["prompt"], np.asarray(res_trunc.tokens), P, TASK)
    print(f"teacher truncated to {float(res_trunc.steps.mean()):.1f} steps: "
          f"score={s_trunc:.2f}")
    if s_teacher > 0.5:
        assert s_student > s_teacher - 0.15
        assert s_trunc < s_student
    else:
        assert s_student >= s_teacher - 0.05
        assert s_trunc <= s_teacher + 0.05


@pytest.mark.slow
def test_serving_engine_end_to_end(pipeline):
    corpus, _, student = pipeline
    from repro.configs.base import ServeConfig
    serve = ServeConfig(max_batch=8, block_size=CDLM_CFG.block_size,
                        gen_length=TASK.gen_len, sampler="cdlm")
    eng = Engine(student, CFG, serve, prompt_len=TASK.prompt_len)
    ev = corpus.eval_batch(16)
    reqs = [Request(prompt=p, id=i) for i, p in enumerate(ev["prompt"])]
    eng.warmup()
    resp = eng.generate(reqs)
    assert len(resp) == 16
    rep = efficiency_report(resp)
    assert rep["steps"] <= TASK.gen_len
    assert rep["tps"] > 0
    assert all(r.tokens.shape == (TASK.gen_len,) for r in resp)
