"""Trajectory encoding (Alg. 1) and (y, y*) pair construction (Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import trajectory as T

MASK = 511


def test_state_at_endpoints():
    final = jnp.asarray([[3, 4, 5, 6]])
    fat = jnp.asarray([[2, 0, 3, 1]])
    y0 = T.state_at(final, fat, 0, MASK)
    yN = T.state_at(final, fat, 4, MASK)
    assert (np.asarray(y0) == MASK).all()
    assert (np.asarray(yN) == np.asarray(final)).all()


def test_state_at_monotone():
    final = jnp.arange(8)[None] + 10
    fat = jnp.asarray([[0, 3, 1, 2, 5, 4, 7, 6]])
    prev_unmasked = -1
    for s in range(9):
        y = np.asarray(T.state_at(final, fat, s, MASK))
        n = int((y != MASK).sum())
        assert n == s  # exactly one token revealed per step
        assert n >= prev_unmasked
        prev_unmasked = n


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 31), st.integers(1, 3))
def test_block_completion_step(t_start, bpow):
    B = 2 ** bpow * 4
    t_end = T.block_completion_step(t_start, B)
    assert t_end > t_start
    assert t_end - t_start <= B
    assert t_end % B == 0


def test_position_sets_partition():
    fat = jnp.asarray([[0, 1, 2, 3, 4, 5, 6, 7]])
    u, s = T.position_sets(fat, jnp.asarray([2]), jnp.asarray([4]))
    u, s = np.asarray(u[0]), np.asarray(s[0])
    # positions finalized in [2, 4) are U; >= 4 are S; < 2 neither
    assert u.tolist() == [False, False, True, True, False, False, False, False]
    assert s.tolist() == [False, False, False, False, True, True, True, True]
    assert not (u & s).any()


def test_sample_training_pair_shapes():
    from repro.configs.base import CDLMConfig
    from repro.configs.registry import get_config
    cfg = get_config("qwen2-0.5b").reduced(dtype="float32")
    cdlm = CDLMConfig(block_size=4, gen_length=8, prompt_length=8)
    n, G, P = 6, 8, 8
    ds = {
        "prompt": jnp.ones((n, P), jnp.int32),
        "gt": jnp.ones((n, G), jnp.int32) * 2,
        "final": jnp.ones((n, G), jnp.int32) * 3,
        "finalized_at": jnp.tile(jnp.arange(G)[None], (n, 1)),
        "hidden": jnp.zeros((n, G, cfg.d_model)),
    }
    batch = T.sample_training_pair(ds, jax.random.PRNGKey(0), 4, cfg=cfg,
                                   cdlm=cdlm)
    assert batch["y"].shape == (4, P + G)
    assert batch["u_mask"].shape == (4, P + G)
    # prompt positions never selected
    assert not bool(batch["u_mask"][:, :P].any())
    assert not bool(batch["s_mask"][:, :P].any())
    # y is always at least as masked as y*
    y_masked = batch["y"] == cfg.mask_token_id
    ystar_masked = batch["y_star"] == cfg.mask_token_id
    assert bool((ystar_masked <= y_masked).all())
    # U positions: masked in y, unmasked in y*
    u = np.asarray(batch["u_mask"])
    assert bool((np.asarray(y_masked)[u]).all())
    assert not bool((np.asarray(ystar_masked)[u]).any())
