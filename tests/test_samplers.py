"""Decoding-algorithm behavior (paper §4.3 + baselines)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.sampler import (
    SAMPLERS,
    SamplerSpec,
    cdlm,
    fast_dllm_parallel,
    vanilla_blockwise,
)
from repro.models import init_model

CFG = get_config("qwen2-0.5b").reduced(dtype="float32")
P, G, B = 8, 16, 4


@pytest.fixture(scope="module")
def setup():
    params = init_model(jax.random.PRNGKey(0), CFG)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, P), 2,
                                 CFG.vocab_size)
    return params, prompts


@pytest.mark.parametrize("name", sorted(SAMPLERS))
def test_sampler_completes_generation(setup, name):
    params, prompts = setup
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                       conf_threshold=0.5, early_stop=False)
    res = SAMPLERS[name](params, prompts, cfg=CFG, spec=spec)
    toks = np.asarray(res.tokens)
    assert toks.shape == (2, P + G)
    assert (toks[:, :P] == np.asarray(prompts)).all()
    if name != "ar":  # AR writes real tokens, may legitimately emit mask id
        assert (toks[:, P:] != CFG.mask_token_id).all(), name
    assert int(res.steps.max()) <= G


def test_vanilla_steps_equal_gen_len(setup):
    params, prompts = setup
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B)
    res = vanilla_blockwise(params, prompts, cfg=CFG, spec=spec)
    assert (np.asarray(res.steps) == G).all()


def test_threshold_zero_is_one_step_per_block(setup):
    """tau=0 finalizes the whole block at once -> n_blocks steps."""
    params, prompts = setup
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                       conf_threshold=0.0, early_stop=False)
    res = fast_dllm_parallel(params, prompts, cfg=CFG, spec=spec)
    assert (np.asarray(res.steps) == G // B).all()


def test_threshold_monotonicity(setup):
    """Lower tau => fewer (or equal) refinement steps (App. B.2 trend)."""
    params, prompts = setup
    steps = []
    for tau in (0.0, 0.5, 0.999):
        spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                           conf_threshold=tau, early_stop=False)
        res = cdlm(params, prompts, cfg=CFG, spec=spec)
        steps.append(int(res.steps.sum()))
    assert steps[0] <= steps[1] <= steps[2]
    assert steps[0] == 2 * (G // B)  # tau=0: one step per block per seq


def test_trajectory_recording(setup):
    params, prompts = setup
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B)
    res, finalized_at, hidden = vanilla_blockwise(
        params, prompts, cfg=CFG, spec=spec, record_hidden=True)
    fat = np.asarray(finalized_at)
    # every generated position finalized exactly once, steps 0..G-1 used once
    assert (np.sort(fat, axis=1) == np.arange(G)).all()
    # block-wise order: earlier blocks finalized at earlier step ranges
    for blk in range(G // B):
        sel = fat[:, blk * B:(blk + 1) * B]
        assert (sel >= blk * B).all() and (sel < (blk + 1) * B).all()
    assert np.abs(np.asarray(hidden)).sum() > 0


def test_cdlm_early_stop_reduces_steps(setup):
    """Force EOS-heavy logits by biasing the head; early_stop must not
    increase steps and gen_lengths must shrink."""
    params, prompts = setup
    # bias head toward EOS so the first block emits it
    params2 = jax.tree_util.tree_map(lambda x: x, params)
    head = params2["embed"]["tok"]
    params2["embed"]["tok"] = head.at[CFG.eos_token_id].set(head[CFG.eos_token_id] + 3.0)
    spec_on = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                          conf_threshold=0.0, early_stop=True)
    spec_off = SamplerSpec(prompt_len=P, gen_len=G, block_size=B,
                           conf_threshold=0.0, early_stop=False)
    r_on = cdlm(params2, prompts, cfg=CFG, spec=spec_on)
    r_off = cdlm(params2, prompts, cfg=CFG, spec=spec_off)
    assert int(r_on.steps.sum()) <= int(r_off.steps.sum())
    assert int(r_on.gen_lengths.max()) <= G


def test_gen_lengths_eos_semantics():
    from repro.core.sampler import _gen_lengths
    spec = SamplerSpec(prompt_len=2, gen_len=4, block_size=2)
    toks = jnp.asarray([[5, 5, 9, CFG.eos_token_id, 9, 9],
                        [5, 5, 9, 9, 9, 9]])
    gl = _gen_lengths(toks, spec, CFG)
    assert gl.tolist() == [1, 4]
