"""Per-lane cache primitives, both layouts.

Dense: ``reset`` / ``commit_rows`` on *non-contiguous* lane subsets (the
serving scheduler recycles arbitrary lanes, not prefixes). Paged: page
alloc/free/commit mechanics, and THE reuse invariant — pages freed by an
evicted request and re-allocated to a new one decode bit-identically to a
fresh pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import cache as C
from repro.core import masks
from repro.models import forward, init_model

CFG = get_config("qwen2-0.5b").reduced(dtype="float32")
P, B, G = 8, 4, 8
T = P + G


@pytest.fixture(scope="module")
def params():
    return init_model(jax.random.PRNGKey(0), CFG)


def _emissions(params, tokens, L):
    out = forward(params, tokens[:, :L], cfg=CFG, mode=masks.BLOCK_CAUSAL,
                  prompt_len=P, block_size=B)
    return out.emissions


# ---------------------------------------------------------------------------
# Dense per-lane paths on non-contiguous subsets
# ---------------------------------------------------------------------------
def test_reset_noncontiguous_lanes(params):
    b = 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, T), 2,
                                CFG.vocab_size)
    cache = C.init_cache(CFG, b, T, dtype="float32")
    cache = C.commit(cache, _emissions(params, tokens, P), 0)
    rows = jnp.array([True, False, True, False])
    out = C.reset(cache, rows)
    for cs, os_ in zip(cache, out):
        for k in cs:
            old, new = np.asarray(cs[k]), np.asarray(os_[k])
            assert (new[:, 0] == 0).all() and (new[:, 2] == 0).all(), k
            assert np.array_equal(new[:, 1], old[:, 1]), k
            assert np.array_equal(new[:, 3], old[:, 3]), k


def test_reset_accepts_int_indices(params):
    b = 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, T), 2,
                                CFG.vocab_size)
    cache = C.commit(C.init_cache(CFG, b, T, dtype="float32"),
                     _emissions(params, tokens, P), 0)
    by_mask = C.reset(cache, jnp.array([True, False, False, True]))
    by_idx = C.reset(cache, jnp.array([0, 3]))
    for a, c in zip(jax.tree_util.tree_leaves(by_mask),
                    jax.tree_util.tree_leaves(by_idx)):
        assert np.array_equal(np.asarray(a), np.asarray(c))


def test_commit_rows_noncontiguous_distinct_offsets(params):
    """Lanes {0, 3} of 4 commit at *different* offsets; lanes {1, 2} must be
    bit-untouched, and each written lane must match a solo dense commit at
    its own offset."""
    b = 4
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, T), 2,
                                CFG.vocab_size)
    base = C.commit(C.init_cache(CFG, b, T, dtype="float32"),
                    _emissions(params, tokens, P), 0)
    em = _emissions(params, tokens[:, P:P + B], B)
    rows = jnp.array([True, False, False, True])
    offsets = jnp.array([P, 0, 0, P + B])
    got = C.commit_rows(base, em, offsets, rows)
    for lane, off in ((0, P), (3, P + B)):
        solo = C.commit(
            jax.tree_util.tree_map(lambda a: a[:, lane:lane + 1], base),
            jax.tree_util.tree_map(lambda a: a[:, lane:lane + 1], em), off)
        for gs, ss in zip(got, solo):
            for k in gs:
                assert np.array_equal(np.asarray(gs[k][:, lane]),
                                      np.asarray(ss[k][:, 0])), (lane, k)
    for lane in (1, 2):
        for gs, bs in zip(got, base):
            for k in gs:
                assert np.array_equal(np.asarray(gs[k][:, lane]),
                                      np.asarray(bs[k][:, lane])), (lane, k)


# ---------------------------------------------------------------------------
# Paged mechanics
# ---------------------------------------------------------------------------
def test_alloc_lowest_first_all_or_nothing():
    paged = C.init_paged_cache(CFG, 2, T, n_pages=4, page_size=B,
                               dtype="float32")
    paged, ok = C.alloc(paged, jnp.array([True, False]), 0, 3 * B)
    assert bool(ok[0]) and not bool(ok[1])
    assert np.asarray(paged.page_table)[0, :3].tolist() == [0, 1, 2]
    # lane 1 wants 2 pages: only 1 free -> all-or-nothing failure, table
    # stays clean
    paged, ok = C.alloc(paged, jnp.array([False, True]), 0, 2 * B)
    assert not bool(ok[1])
    assert (np.asarray(paged.page_table)[1] == C.FREE).all()
    # 1 page fits
    paged, ok = C.alloc(paged, jnp.array([False, True]), 0, B)
    assert bool(ok[1])
    assert np.asarray(paged.page_table)[1, 0] == 3
    assert int(C.free_page_count(paged)) == 0


def test_alloc_lane_priority_order():
    """Two lanes compete for 3 free pages, each wanting 2: the lower lane
    index wins, the other fails cleanly."""
    paged = C.init_paged_cache(CFG, 2, T, n_pages=3, page_size=B,
                               dtype="float32")
    paged, ok = C.alloc(paged, jnp.array([True, True]), 0, 2 * B)
    assert bool(ok[0]) and not bool(ok[1])
    assert int(C.free_page_count(paged)) == 1


def test_free_returns_pages_and_clears_state():
    paged = C.init_paged_cache(CFG, 2, T, n_pages=6, page_size=B,
                               dtype="float32")
    paged, _ = C.alloc(paged, jnp.array([True, True]), 0, 2 * B)
    assert int(C.free_page_count(paged)) == 2
    paged = C.free(paged, jnp.array([True, False]))
    assert int(C.free_page_count(paged)) == 4
    tbl = np.asarray(paged.page_table)
    assert (tbl[0] == C.FREE).all() and (tbl[1] != C.FREE).any()
    # lane 1's pages still owned by lane 1
    owner = np.asarray(paged.page_owner)
    assert (owner[tbl[1][tbl[1] != C.FREE]] == 1).all()


def test_commit_rows_paged_respects_mask(params):
    b = 2
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, T), 2,
                                CFG.vocab_size)
    paged = C.init_paged_cache(CFG, b, T, n_pages=6, page_size=B,
                               dtype="float32")
    paged, _ = C.alloc(paged, jnp.ones((b,), bool), 0, P)
    em = _emissions(params, tokens, P)
    sel = C.commit_rows(paged, em, 0, jnp.array([True, False]))
    tbl = np.asarray(paged.page_table)
    for slot in sel.slots:
        for k in ("k", "v"):
            if k in slot:
                pool = np.asarray(slot[k])
                # lane 1's pages must still be zero-initialized
                assert (pool[:, tbl[1, 0]] == 0).all(), k
                assert (pool[:, tbl[0, 0]] != 0).any(), k


def test_gather_dense_view_matches_dense_cache(params):
    b = 2
    tokens = jax.random.randint(jax.random.PRNGKey(4), (b, T), 2,
                                CFG.vocab_size)
    em = _emissions(params, tokens, P)
    rows = jnp.ones((b,), bool)
    dense = C.commit_rows(C.init_cache(CFG, b, T, dtype="float32"), em, 0,
                          rows)
    paged = C.init_paged_cache(CFG, b, T, n_pages=2 * (T // B), page_size=B,
                               dtype="float32")
    paged, _ = C.alloc(paged, rows, 0, T)
    paged = C.commit_rows(paged, em, 0, rows)
    view = C.gather_dense(paged)
    for ds, ps in zip(dense, view):
        for k in ds:
            assert np.array_equal(np.asarray(ds[k][:, :, :P]),
                                  np.asarray(ps[k][:, :, :P])), k


def test_page_reuse_after_eviction_decodes_identically(params):
    """Pages dirtied by one request, freed, and re-allocated to another must
    decode bit-identically to a fresh pool — the eviction invariant the
    continuous scheduler rests on."""
    prompt = jax.random.randint(jax.random.PRNGKey(5), (1, T), 2,
                                CFG.vocab_size)
    other = jax.random.randint(jax.random.PRNGKey(6), (1, T), 2,
                               CFG.vocab_size)
    rows = jnp.ones((1,), bool)

    def decode_logits(paged):
        paged, ok = C.alloc(paged, rows, 0, P + B)
        assert bool(ok.all())
        paged = C.commit_rows(paged, _emissions(params, prompt, P), 0, rows)
        out = forward(params, prompt[:, P:P + B], cfg=CFG,
                      mode=masks.BLOCK_CAUSAL, prompt_len=P, block_size=B,
                      positions=P + jnp.arange(B), cache=paged, cache_len=P)
        return np.asarray(out.logits)

    fresh = C.init_paged_cache(CFG, 1, T, n_pages=T // B, page_size=B,
                               dtype="float32")
    want = decode_logits(fresh)

    dirty = C.init_paged_cache(CFG, 1, T, n_pages=T // B, page_size=B,
                               dtype="float32")
    dirty, _ = C.alloc(dirty, rows, 0, T)          # other request takes all
    dirty = C.commit_rows(dirty, _emissions(params, other, T), 0, rows)
    dirty = C.free(dirty, rows)                    # evicted
    got = decode_logits(dirty)                     # recycled pages
    assert np.array_equal(want, got)


def test_paged_rejects_attention_free():
    rwkv = get_config("rwkv6-1.6b").reduced(dtype="float32")
    with pytest.raises(ValueError, match="attention"):
        C.init_paged_cache(rwkv, 1, T, n_pages=4, page_size=B,
                           dtype="float32")
