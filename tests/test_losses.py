"""CDLM objective correctness (Eqs. 4–7)."""
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.core import losses as LS


def test_forward_kl_identity_is_zero():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 7))
    kl = LS.forward_kl(logits, logits)
    assert float(jnp.max(jnp.abs(kl))) < 1e-6


def test_forward_kl_nonnegative_and_asymmetric():
    p = jax.random.normal(jax.random.PRNGKey(1), (5, 11))
    q = jax.random.normal(jax.random.PRNGKey(2), (5, 11))
    f = LS.forward_kl(p, q)
    r = LS.reverse_kl(p, q)
    assert bool((f > -1e-6).all())
    assert float(jnp.max(jnp.abs(f - r))) > 1e-4


def test_distillation_loss_only_on_u_mask():
    k = jax.random.PRNGKey(0)
    s = jax.random.normal(k, (2, 6, 9))
    t = jax.random.normal(jax.random.PRNGKey(3), (2, 6, 9))
    none = LS.distillation_loss(s, t, jnp.zeros((2, 6), bool))
    assert float(none) == 0.0
    one_pos = jnp.zeros((2, 6), bool).at[0, 2].set(True)
    got = LS.distillation_loss(s, t, one_pos)
    want = LS.forward_kl(t, s)[0, 2]
    assert abs(float(got) - float(want)) < 1e-6


def test_consistency_loss_stop_gradient():
    """Gradient must flow only through the y branch (q_{phi^-} detached)."""
    def loss(w):
        logits_y = w * jnp.ones((1, 2, 4))
        logits_ystar = w * 2 * jnp.ones((1, 2, 4))
        return LS.consistency_loss(logits_y, logits_ystar,
                                   jnp.ones((1, 2), bool))
    jax.grad(loss)(jnp.asarray(1.0))
    # constant logits -> uniform distributions -> zero loss AND the target
    # branch contributes no gradient; perturb to check flow:
    def loss2(wy, wstar):
        ly = jnp.stack([wy, 2 * wy, 0 * wy, -wy])[None, None]
        ls = jnp.stack([wstar, -wstar, wstar, 0 * wstar])[None, None]
        return LS.consistency_loss(ly, ls, jnp.ones((1, 1), bool))
    gy = jax.grad(loss2, argnums=0)(1.0, 1.0)
    gs = jax.grad(loss2, argnums=1)(1.0, 1.0)
    assert abs(gy) > 1e-6      # student-at-y receives gradient
    assert abs(gs) < 1e-12     # stop-grad target does not


def test_dlm_loss_matches_manual():
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (2, 4, 8))
    targets = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 0]])
    masked = jnp.asarray([[True, False, True, False],
                          [False, False, False, False]])
    t = jnp.asarray([0.5, 0.5])
    got = LS.dlm_loss(logits, targets, masked, t)
    logp = jax.nn.log_softmax(logits, -1)
    manual = -(logp[0, 0, 1] + logp[0, 2, 3]) / 0.5
    manual = (manual + 0.0) / 2 / 4  # batch mean, /gen_len
    assert abs(float(got) - float(manual)) < 1e-5


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 2.0), st.floats(0.0, 2.0), st.floats(0.0, 2.0))
def test_total_is_linear(wd, wc, wm):
    t = LS.cdlm_total(1.0, 2.0, 3.0, w_distill=wd, w_cons=wc, w_dlm=wm)
    assert abs(float(t) - (wd + 2 * wc + 3 * wm)) < 1e-6
