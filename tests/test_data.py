"""Synthetic-task verifiers and pipeline determinism."""
import numpy as np

from repro.data import Corpus, TaskSpec, answer_mask, sample_batch, verify
from repro.data.synthetic import ASK, DIGIT0, EOS, PLUS


def test_sort_task_verifier_accepts_truth():
    spec = TaskSpec("sort", vocab_size=512, prompt_len=12, gen_len=12,
                    sort_k=6, sort_range=32)
    rng = np.random.default_rng(0)
    b = sample_batch(rng, spec, 16)
    for p, a in zip(b["prompt"], b["answer"]):
        assert verify(p, a, spec)
        # corrupt one token -> reject
        bad = a.copy()
        bad[0] = DIGIT0 + ((bad[0] - DIGIT0 + 1) % 32)
        assert not verify(p, bad, spec)


def test_add_task_verifier():
    spec = TaskSpec("add", vocab_size=512, prompt_len=16, gen_len=10,
                    add_digits=4)
    rng = np.random.default_rng(1)
    b = sample_batch(rng, spec, 16)
    for p, a in zip(b["prompt"], b["answer"]):
        assert verify(p, a, spec)


def test_add_answers_are_actual_sums():
    spec = TaskSpec("add", vocab_size=512, prompt_len=16, gen_len=10,
                    add_digits=3)
    rng = np.random.default_rng(2)
    b = sample_batch(rng, spec, 8)
    p = b["prompt"][0].tolist()
    plus, ask = p.index(PLUS), p.index(ASK)
    a_val = int("".join(str(t - DIGIT0) for t in p[1:plus]))
    b_val = int("".join(str(t - DIGIT0) for t in p[plus + 1:ask]))
    ans = b["answer"][0].tolist()
    got = int("".join(str(t - DIGIT0) for t in ans[:ans.index(EOS)]))
    assert got == a_val + b_val


def test_answer_mask_covers_through_eos():
    ans = np.asarray([[11, 12, EOS, 0, 0]])
    m = answer_mask(ans)
    assert m.tolist() == [[True, True, True, False, False]]


def test_corpus_determinism_and_batching():
    spec = TaskSpec("sort", vocab_size=512, prompt_len=12, gen_len=12,
                    sort_k=6, sort_range=32)
    c1 = Corpus(spec, 64, seed=7)
    c2 = Corpus(spec, 64, seed=7)
    assert (c1.prompt == c2.prompt).all()
    batches = list(c1.batches(16, seed=0, epochs=1))
    assert len(batches) == 4
    assert batches[0]["prompt"].shape == (16, 12)
