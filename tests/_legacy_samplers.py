"""FROZEN seed-PR sampler implementations (verbatim from git history).

These are the six hand-rolled block loops that the unified engine in
``repro.core.block_loop`` replaced. They exist ONLY as the reference for
the equivalence tests in ``tests/test_block_loop.py`` proving that each
``DecodeStrategy`` port is bit-identical (tokens, steps, n_model_calls,
gen_lengths) to the seed behavior. Do not modify and do not import from
production code.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cache as C
from repro.core import diffusion as D
from repro.core import masks
from repro.models import forward


class SampleResult(NamedTuple):
    tokens: jnp.ndarray         # (b, prompt+gen) canvas
    steps: jnp.ndarray          # (b,) refinement iterations
    n_model_calls: jnp.ndarray  # scalar, total forward passes
    gen_lengths: jnp.ndarray    # (b,) tokens before EOS


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    prompt_len: int             # text prompt tokens in the canvas
    gen_len: int
    block_size: int
    conf_threshold: float = 0.9
    temperature: float = 0.0
    early_stop: bool = True
    cache_refresh_interval: int = 8
    attn_impl: str = "auto"
    pos_offset: int = 0         # prefix embeds (VLM patches) before canvas

    @property
    def n_blocks(self) -> int:
        return self.gen_len // self.block_size

    @property
    def full_prompt_len(self) -> int:
        return self.prompt_len + self.pos_offset


def init_canvas(prompt_tokens, spec: SamplerSpec, cfg: ModelConfig):
    b = prompt_tokens.shape[0]
    gen = jnp.full((b, spec.gen_len), cfg.mask_token_id, prompt_tokens.dtype)
    return jnp.concatenate([prompt_tokens, gen], axis=1)


def _gen_lengths(tokens, spec: SamplerSpec, cfg: ModelConfig):
    gen = tokens[:, spec.prompt_len:]
    is_eos = gen == cfg.eos_token_id
    has = jnp.any(is_eos, axis=-1)
    first = jnp.argmax(is_eos, axis=-1)
    return jnp.where(has, first, spec.gen_len)


def _block_pos_mask(T: int, start: int, size: int):
    pos = jnp.arange(T)
    return (pos >= start) & (pos < start + size)


def _full_logits(params, tokens, cfg, spec, mode, extras):
    """Full forward over the canvas (+ prefix embeds); returns the model
    output with logits/hidden sliced back to canvas coordinates."""
    out = forward(params, tokens, cfg=cfg, mode=mode,
                  prompt_len=spec.full_prompt_len, block_size=spec.block_size,
                  attn_impl=spec.attn_impl, **extras)
    if spec.pos_offset:
        out = out._replace(logits=out.logits[:, spec.pos_offset:],
                           hidden=out.hidden[:, spec.pos_offset:])
    return out


def _dec_extras(extras):
    return {k: v for k, v in extras.items()
            if k not in ("encoder_embeds", "prefix_embeds")}


# ---------------------------------------------------------------------------
# Full-recompute samplers (teacher-side)
# ---------------------------------------------------------------------------
def vanilla_blockwise(params, prompt_tokens, *, cfg: ModelConfig,
                      spec: SamplerSpec, key=None, extras=None,
                      record_hidden: bool = False):
    """Alg. 1 teacher decoding: N = L_g steps, one token finalized per step.

    With ``record_hidden`` also returns ``finalized_at`` (b, L_g) — the step
    index at which each position was finalized (a compact, exact encoding of
    the monotone trajectory T_x) — and the hidden buffer H (b, L_g, d)."""
    extras = extras or {}
    key = key if key is not None else jax.random.PRNGKey(0)
    tokens = init_canvas(prompt_tokens, spec, cfg)
    b, T = tokens.shape
    P, B, G = spec.prompt_len, spec.block_size, spec.gen_len
    finalized_at = jnp.full((b, G), -1, jnp.int32)
    hidden_buf = jnp.zeros((b, G, cfg.d_model), jnp.float32)
    step_counter = 0

    for blk in range(spec.n_blocks):
        start = P + blk * B
        bmask = _block_pos_mask(T, start, B)
        for _ in range(B):
            key, sub = jax.random.split(key)
            out = _full_logits(params, tokens, cfg, spec,
                               masks.BIDIRECTIONAL, extras)
            cand, conf = D.confidence_and_candidates(
                out.logits, tokens, cfg.mask_token_id, spec.temperature, sub)
            sel = D.select_topk_in_block(conf, bmask[None, :], 1)
            tokens = jnp.where(sel, cand.astype(tokens.dtype), tokens)
            if record_hidden:
                gen_sel = sel[:, P:]
                finalized_at = jnp.where(gen_sel, step_counter, finalized_at)
                hidden_buf = jnp.where(
                    gen_sel[..., None], out.hidden[:, P:].astype(jnp.float32),
                    hidden_buf)
            step_counter += 1

    steps = jnp.full((b,), step_counter, jnp.int32)
    res = SampleResult(tokens, steps, jnp.asarray(step_counter, jnp.int32),
                       _gen_lengths(tokens, spec, cfg))
    if record_hidden:
        return res, finalized_at, hidden_buf
    return res


def _threshold_update(tokens, logits_canvas, bmask, spec, cfg, key, active):
    cand, conf = D.confidence_and_candidates(
        logits_canvas, tokens, cfg.mask_token_id, spec.temperature, key)
    sel = D.select_threshold_in_block(conf, bmask[None, :], spec.conf_threshold)
    sel = sel & active[:, None]
    return jnp.where(sel, cand.astype(tokens.dtype), tokens)


def fast_dllm_parallel(params, prompt_tokens, *, cfg: ModelConfig,
                       spec: SamplerSpec, key=None, extras=None):
    """Fast-dLLM (Parallel): threshold finalization, full recompute."""
    extras = extras or {}
    key = key if key is not None else jax.random.PRNGKey(0)
    tokens = init_canvas(prompt_tokens, spec, cfg)
    b, T = tokens.shape
    P, B = spec.prompt_len, spec.block_size
    steps = jnp.zeros((b,), jnp.int32)
    calls = jnp.zeros((), jnp.int32)
    done = jnp.zeros((b,), bool)

    for blk in range(spec.n_blocks):
        start = P + blk * B
        bmask = _block_pos_mask(T, start, B)

        def cond(st):
            tokens, steps, calls, key, done, it = st
            masked = jnp.any((tokens == cfg.mask_token_id) & bmask[None, :]
                             & ~done[:, None], axis=-1)
            return jnp.any(masked) & (it < B)

        def body(st):
            tokens, steps, calls, key, done, it = st
            key, sub = jax.random.split(key)
            out = _full_logits(params, tokens, cfg, spec,
                               masks.BIDIRECTIONAL, extras)
            active = jnp.any((tokens == cfg.mask_token_id) & bmask[None, :],
                             axis=-1) & ~done
            tokens = _threshold_update(tokens, out.logits, bmask, spec, cfg,
                                       sub, active)
            return (tokens, steps + active.astype(jnp.int32), calls + 1,
                    key, done, it + 1)

        tokens, steps, calls, key, done, _ = jax.lax.while_loop(
            cond, body,
            (tokens, steps, calls, key, done, jnp.zeros((), jnp.int32)))
        if spec.early_stop:
            done = done | jnp.any(
                (tokens == cfg.eos_token_id) & bmask[None, :], -1)

    return SampleResult(tokens, steps, calls, _gen_lengths(tokens, spec, cfg))


# ---------------------------------------------------------------------------
# Approximate-cache samplers (training-free baselines)
# ---------------------------------------------------------------------------
def _refresh_cache(params, tokens, cfg, spec, kv_cache, extras):
    """Full bidirectional forward; commit KV for every position."""
    out = forward(params, tokens, cfg=cfg, mode=masks.BIDIRECTIONAL,
                  prompt_len=spec.full_prompt_len, block_size=spec.block_size,
                  attn_impl=spec.attn_impl, **extras)
    return C.commit(kv_cache, out.emissions, 0)


def _approx_cache_sampler(params, prompt_tokens, *, cfg, spec, key, extras,
                          refresh_every_block: bool):
    extras = extras or {}
    key = key if key is not None else jax.random.PRNGKey(0)
    tokens = init_canvas(prompt_tokens, spec, cfg)
    b, T = tokens.shape
    P, B, off = spec.prompt_len, spec.block_size, spec.pos_offset
    S = T + off
    kv_cache = C.init_cache(cfg, b, S, dtype=cfg.dtype)
    kv_cache = _refresh_cache(params, tokens, cfg, spec, kv_cache, extras)
    steps = jnp.zeros((b,), jnp.int32)
    calls = jnp.ones((), jnp.int32)
    done = jnp.zeros((b,), bool)
    R = spec.cache_refresh_interval
    dx = _dec_extras(extras)

    for blk in range(spec.n_blocks):
        start = P + blk * B                  # canvas coords
        astart = start + off                 # absolute sequence coords
        bmask = _block_pos_mask(T, start, B)
        # stale cache entries for the active block itself are invalid —
        # fresh block KV is computed every step (dual-cache semantics).
        cache_valid = ~_block_pos_mask(S, astart, B)

        def block_out(tokens, kv_cache):
            block_tokens = jax.lax.dynamic_slice_in_dim(tokens, start, B, 1)
            return forward(params, block_tokens, cfg=cfg,
                           mode=masks.BIDIRECTIONAL,
                           prompt_len=spec.full_prompt_len, block_size=B,
                           positions=astart + jnp.arange(B), cache=kv_cache,
                           cache_len=astart, cache_valid=cache_valid,
                           attn_impl=spec.attn_impl, **dx)

        if refresh_every_block and blk > 0:
            kv_cache = _refresh_cache(params, tokens, cfg, spec, kv_cache,
                                      extras)
            calls = calls + 1

        def cond(st):
            tokens, kv_cache, steps, calls, key, done, it = st
            masked = jnp.any((tokens == cfg.mask_token_id) & bmask[None, :]
                             & ~done[:, None], axis=-1)
            return jnp.any(masked) & (it < B)

        def body(st):
            tokens, kv_cache, steps, calls, key, done, it = st
            key, sub = jax.random.split(key)
            if not refresh_every_block:
                kv_cache = jax.lax.cond(
                    (it % R) == (R - 1),
                    lambda c: _refresh_cache(params, tokens, cfg, spec, c,
                                             extras),
                    lambda c: c, kv_cache)
            out = block_out(tokens, kv_cache)
            logits_canvas = jnp.zeros((b, T, out.logits.shape[-1]),
                                      out.logits.dtype)
            logits_canvas = jax.lax.dynamic_update_slice_in_dim(
                logits_canvas, out.logits, start, 1)
            active = jnp.any((tokens == cfg.mask_token_id) & bmask[None, :],
                             axis=-1) & ~done
            tokens = _threshold_update(tokens, logits_canvas, bmask, spec,
                                       cfg, sub, active)
            return (tokens, kv_cache, steps + active.astype(jnp.int32),
                    calls + 1, key, done, it + 1)

        tokens, kv_cache, steps, calls, key, done, _ = jax.lax.while_loop(
            cond, body,
            (tokens, kv_cache, steps, calls, key, done,
             jnp.zeros((), jnp.int32)))
        if spec.early_stop:
            done = done | jnp.any(
                (tokens == cfg.eos_token_id) & bmask[None, :], -1)

    return SampleResult(tokens, steps, calls, _gen_lengths(tokens, spec, cfg))


def dual_cache(params, prompt_tokens, *, cfg, spec, key=None, extras=None):
    return _approx_cache_sampler(params, prompt_tokens, cfg=cfg, spec=spec,
                                 key=key, extras=extras,
                                 refresh_every_block=True)


def interval_cache(params, prompt_tokens, *, cfg, spec, key=None, extras=None):
    return _approx_cache_sampler(params, prompt_tokens, cfg=cfg, spec=spec,
                                 key=key, extras=extras,
                                 refresh_every_block=False)


# ---------------------------------------------------------------------------
# CDLM student decoding (paper §4.3) — exact block-causal cache
# ---------------------------------------------------------------------------
def cdlm(params, prompt_tokens, *, cfg: ModelConfig, spec: SamplerSpec,
         key=None, extras=None, use_long_window: bool = False):
    extras = extras or {}
    key = key if key is not None else jax.random.PRNGKey(0)
    tokens = init_canvas(prompt_tokens, spec, cfg)
    b, T = tokens.shape
    P, B, off = spec.prompt_len, spec.block_size, spec.pos_offset
    S = T + off
    kv_cache = C.init_cache(cfg, b, S, dtype=cfg.dtype)
    dx = _dec_extras(extras)

    # ---- prefill: prompt (+ prefix embeds) under the block-causal mask ----
    out = forward(params, tokens[:, :P], cfg=cfg, mode=masks.BLOCK_CAUSAL,
                  prompt_len=spec.full_prompt_len, block_size=B,
                  attn_impl=spec.attn_impl, **extras)
    kv_cache = C.commit(kv_cache, out.emissions, 0)
    calls = jnp.ones((), jnp.int32)
    steps = jnp.zeros((b,), jnp.int32)
    done = jnp.zeros((b,), bool)

    for blk in range(spec.n_blocks):
        start = P + blk * B
        astart = start + off
        bmask = _block_pos_mask(T, start, B)

        def block_out(tokens, kv_cache):
            block_tokens = jax.lax.dynamic_slice_in_dim(tokens, start, B, 1)
            return forward(params, block_tokens, cfg=cfg,
                           mode=masks.BLOCK_CAUSAL,
                           prompt_len=spec.full_prompt_len, block_size=B,
                           positions=astart + jnp.arange(B), cache=kv_cache,
                           cache_len=astart, use_long_window=use_long_window,
                           attn_impl=spec.attn_impl, **dx)

        def cond(st):
            tokens, steps, calls, key, done, it = st
            masked = jnp.any((tokens == cfg.mask_token_id) & bmask[None, :]
                             & ~done[:, None], axis=-1)
            return jnp.any(masked) & (it < B)

        def body(st):
            tokens, steps, calls, key, done, it = st
            key, sub = jax.random.split(key)
            out = block_out(tokens, kv_cache)
            logits_canvas = jnp.zeros((b, T, out.logits.shape[-1]),
                                      out.logits.dtype)
            logits_canvas = jax.lax.dynamic_update_slice_in_dim(
                logits_canvas, out.logits, start, 1)
            active = jnp.any((tokens == cfg.mask_token_id) & bmask[None, :],
                             axis=-1) & ~done
            tokens = _threshold_update(tokens, logits_canvas, bmask, spec,
                                       cfg, sub, active)
            return (tokens, steps + active.astype(jnp.int32), calls + 1, key,
                    done, it + 1)

        tokens, steps, calls, key, done, _ = jax.lax.while_loop(
            cond, body,
            (tokens, steps, calls, key, done, jnp.zeros((), jnp.int32)))

        # ---- commit pass: recompute the finalized block's KV exactly ----
        out = block_out(tokens, kv_cache)
        kv_cache = C.commit(kv_cache, out.emissions, astart)
        calls = calls + 1

        if spec.early_stop:
            done = done | jnp.any(
                (tokens == cfg.eos_token_id) & bmask[None, :], -1)

    return SampleResult(tokens, steps, calls, _gen_lengths(tokens, spec, cfg))


# ---------------------------------------------------------------------------
# Autoregressive baseline (Fig. 3) — also the RWKV6 decode path
# ---------------------------------------------------------------------------
def ar(params, prompt_tokens, *, cfg: ModelConfig, spec: SamplerSpec,
       key=None, extras=None):
    extras = extras or {}
    tokens = init_canvas(prompt_tokens, spec, cfg)
    b, T = tokens.shape
    P, off = spec.prompt_len, spec.pos_offset
    S = T + off
    kv_cache = C.init_cache(cfg, b, S, dtype=cfg.dtype)
    out = forward(params, tokens[:, :P], cfg=cfg, mode=masks.CAUSAL,
                  attn_impl=spec.attn_impl, **extras)
    kv_cache = C.commit(kv_cache, out.emissions, 0)
    last_logits = out.logits[:, -1]
    dx = _dec_extras(extras)

    def body(i, st):
        tokens, kv_cache, last_logits, done, steps, calls = st
        pos = P + i
        nxt = jnp.argmax(last_logits, axis=-1).astype(tokens.dtype)
        nxt = jnp.where(done, jnp.asarray(cfg.eos_token_id, tokens.dtype), nxt)
        tokens = jax.lax.dynamic_update_slice(tokens, nxt[:, None], (0, pos))
        steps = steps + (~done).astype(jnp.int32)
        done = done | (nxt == cfg.eos_token_id)
        out = forward(params, nxt[:, None], cfg=cfg, mode=masks.CAUSAL,
                      positions=(pos + off)[None], cache=kv_cache,
                      cache_len=pos + off, attn_impl=spec.attn_impl, **dx)
        kv_cache = C.commit(kv_cache, out.emissions, pos + off)
        return (tokens, kv_cache, out.logits[:, -1], done, steps, calls + 1)

    done = jnp.zeros((b,), bool)
    steps = jnp.zeros((b,), jnp.int32)
    calls = jnp.ones((), jnp.int32)
    tokens, kv_cache, last_logits, done, steps, calls = jax.lax.fori_loop(
        0, spec.gen_len, body,
        (tokens, kv_cache, last_logits, done, steps, calls))

    return SampleResult(tokens, steps, calls, _gen_lengths(tokens, spec, cfg))


SAMPLERS = {
    "vanilla": vanilla_blockwise,
    "fast_dllm": fast_dllm_parallel,
    "dual_cache": dual_cache,
    "interval_cache": interval_cache,
    "cdlm": cdlm,
    "ar": ar,
}
