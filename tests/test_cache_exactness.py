"""THE paper invariant: block-causal KV caching is *exact* — a cached block
decode step must reproduce full-recompute logits bit-for-tolerance, for
every architecture family (dense GQA / softcap+SWA / MoE / hybrid SSM /
enc-dec / VLM)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.core import masks
from repro.core import cache as C
from repro.models import forward, init_model

ARCHS = ["qwen2-0.5b", "gemma2-27b", "whisper-base", "kimi-k2-1t-a32b",
         "jamba-v0.1-52b", "llama4-maverick-400b-a17b", "internvl2-1b",
         "gemma-7b", "qwen1.5-110b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_cached_block_decode_matches_recompute(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    b, P, B, G = 2, 8, 4, 8
    T = P + G
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (b, T), 0, cfg.vocab_size)
    extras = {}
    if cfg.is_encoder_decoder:
        extras["encoder_embeds"] = 0.1 * jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model))

    ref = forward(params, tokens[:, :P + B], cfg=cfg,
                  mode=masks.BLOCK_CAUSAL, prompt_len=P, block_size=B,
                  moe_dropless=True, **extras)
    kv = C.init_cache(cfg, b, T, dtype="float32")
    out = forward(params, tokens[:, :P], cfg=cfg, mode=masks.BLOCK_CAUSAL,
                  prompt_len=P, block_size=B, moe_dropless=True, **extras)
    kv = C.commit(kv, out.emissions, 0)
    blk = forward(params, tokens[:, P:P + B], cfg=cfg,
                  mode=masks.BLOCK_CAUSAL, prompt_len=P, block_size=B,
                  positions=P + jnp.arange(B), cache=kv, cache_len=P)
    err = float(jnp.max(jnp.abs(blk.logits - ref.logits[:, P:P + B])))
    assert err < 5e-4, f"{arch}: cached != recompute ({err})"


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "jamba-v0.1-52b"])
def test_second_block_exactness(arch):
    """Commit block 0, decode block 1 — multi-block cache correctness."""
    cfg = get_config(arch).reduced(dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    b, P, B = 1, 8, 4
    T = P + 2 * B
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, T), 0,
                                cfg.vocab_size)
    ref = forward(params, tokens, cfg=cfg, mode=masks.BLOCK_CAUSAL,
                  prompt_len=P, block_size=B, moe_dropless=True)
    kv = C.init_cache(cfg, b, T, dtype="float32")
    out = forward(params, tokens[:, :P], cfg=cfg, mode=masks.BLOCK_CAUSAL,
                  prompt_len=P, block_size=B, moe_dropless=True)
    kv = C.commit(kv, out.emissions, 0)
    blk0 = forward(params, tokens[:, P:P + B], cfg=cfg,
                   mode=masks.BLOCK_CAUSAL, prompt_len=P, block_size=B,
                   positions=P + jnp.arange(B), cache=kv, cache_len=P)
    kv = C.commit(kv, blk0.emissions, P)
    blk1 = forward(params, tokens[:, P + B:P + 2 * B], cfg=cfg,
                   mode=masks.BLOCK_CAUSAL, prompt_len=P, block_size=B,
                   positions=P + B + jnp.arange(B), cache=kv,
                   cache_len=P + B)
    err = float(jnp.max(jnp.abs(blk1.logits - ref.logits[:, P + B:])))
    assert err < 5e-4, err


def test_block_independence_of_future():
    """Student property (Fig. 2): logits of block i are invariant to the
    content of blocks > i."""
    cfg = get_config("qwen2-0.5b").reduced(dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    P, B = 8, 4
    T = P + 8
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, T), 2, cfg.vocab_size)
    t2 = t1.at[:, P + B:].set(7)  # change the future block
    o1 = forward(params, t1, cfg=cfg, mode=masks.BLOCK_CAUSAL,
                 prompt_len=P, block_size=B)
    o2 = forward(params, t2, cfg=cfg, mode=masks.BLOCK_CAUSAL,
                 prompt_len=P, block_size=B)
    diff = float(jnp.max(jnp.abs(o1.logits[:, :P + B] - o2.logits[:, :P + B])))
    assert diff < 1e-5
    # ...whereas the bidirectional teacher is NOT invariant
    o3 = forward(params, t1, cfg=cfg, mode=masks.BIDIRECTIONAL)
    o4 = forward(params, t2, cfg=cfg, mode=masks.BIDIRECTIONAL)
    diff_t = float(jnp.max(jnp.abs(o3.logits[:, :P + B] - o4.logits[:, :P + B])))
    assert diff_t > 1e-4
