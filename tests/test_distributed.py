"""Distribution correctness — runs in subprocesses so each test controls
``--xla_force_host_platform_device_count`` (jax pins device count at init).

- sharding rules produce divisibility-valid specs for every arch;
- a tiny-mesh dry-run (2×4) lowers+compiles a real train & decode step;
- the sequence-parallel shard_map decode matches the single-device oracle.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_sharding_rules_divisibility():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import ARCHITECTURES
        from repro.launch.mesh import make_tiny_mesh
        from repro.launch.specs import abstract_params
        from repro.parallel import param_specs
        mesh = make_tiny_mesh(data=2, model=4)
        for arch, cfg in ARCHITECTURES.items():
            params = abstract_params(cfg)
            specs = param_specs(params, mesh, fsdp=True)
            flat_p = jax.tree_util.tree_leaves(params)
            flat_s = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            n_sharded = 0
            for leaf, spec in zip(flat_p, flat_s):
                for d, ax in enumerate(spec):
                    if ax is None: continue
                    size = mesh.shape[ax] if isinstance(ax, str) else \
                        __import__('numpy').prod([mesh.shape[a] for a in ax])
                    assert leaf.shape[d] % size == 0, (arch, spec, leaf.shape)
                    n_sharded += 1
            assert n_sharded > 0, arch
        print("RULES_OK")
    """)
    assert "RULES_OK" in out


@pytest.mark.slow
def test_tiny_mesh_dryrun_train_and_decode():
    out = run_py("""
        import jax
        from repro.launch.mesh import make_tiny_mesh
        from repro.launch.specs import build_plan
        import repro.launch.specs as S
        mesh = make_tiny_mesh(data=2, model=4)
        # shrink shapes so the tiny mesh compiles fast
        import repro.configs.base as B
        B.INPUT_SHAPES["train_4k"] = B.ShapeConfig("train_4k", 256, 8, "train")
        B.INPUT_SHAPES["decode_32k"] = B.ShapeConfig("decode_32k", 512, 8, "decode")
        for arch in ["qwen2-0.5b", "rwkv6-1.6b"]:
            for shape in ["train_4k", "decode_32k"]:
                plan = build_plan(arch, shape, mesh)
                with mesh:
                    c = jax.jit(plan.fn, in_shardings=plan.in_shardings).lower(*plan.args).compile()
                assert c is not None
                print("OK", arch, shape)
        print("TINY_DRYRUN_OK")
    """, devices=8, timeout=1800)
    assert "TINY_DRYRUN_OK" in out


def test_seq_parallel_decode_matches_oracle():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_tiny_mesh
        from repro.parallel import make_sharded_decode_attention
        from repro.kernels.decode_attn import decode_attention_ref
        mesh = make_tiny_mesh(data=2, model=4)
        b, Bq, Kv, G, hd, S, clen = 2, 8, 2, 2, 16, 64, 50
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q  = jax.random.normal(ks[0], (b, Bq, Kv, G, hd))
        kc = jax.random.normal(ks[1], (b, S, Kv, hd))
        vc = jax.random.normal(ks[2], (b, S, Kv, hd))
        kb = jax.random.normal(ks[3], (b, Bq, Kv, hd))
        vb = jax.random.normal(ks[4], (b, Bq, Kv, hd))
        fn = make_sharded_decode_attention(mesh, batch_axis="data")
        with mesh:
            out = jax.jit(lambda *a: fn(*a, scale=0.25))(q, kc, vc, kb, vb, jnp.asarray(clen))
        ref = decode_attention_ref(q, kc, vc, kb, vb, clen, scale=0.25)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        # windowed variant
        with mesh:
            outw = jax.jit(lambda *a: fn(*a, scale=0.25, window=24))(q, kc, vc, kb, vb, jnp.asarray(clen))
        refw = decode_attention_ref(q, kc, vc, kb, vb, clen, scale=0.25, window=24)
        errw = float(jnp.max(jnp.abs(outw - refw)))
        assert errw < 1e-4, errw
        print("SEQ_DECODE_OK", err, errw)
    """)
    assert "SEQ_DECODE_OK" in out


def test_mesh_shapes():
    out = run_py("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
        m2 = make_production_mesh(multi_pod=True)
        assert m2.devices.shape == (2, 16, 16)
        assert m2.axis_names == ("pod", "data", "model")
        print("MESH_OK")
    """, devices=512)
    assert "MESH_OK" in out
