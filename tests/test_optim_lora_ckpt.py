"""Optimizer math, LoRA equivalence, checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import restore, save
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.models import forward, init_model
from repro.models import lora as LoRA
from repro.optim import adamw


def test_adamw_matches_reference_math():
    tcfg = TrainConfig(learning_rate=0.1, warmup_frac=0.0, grad_clip=1e9,
                       weight_decay=0.0, steps=10)
    params = {"w": jnp.asarray([1.0, 2.0])}
    grads = {"w": jnp.asarray([0.1, -0.2])}
    st = adamw.init(params)
    new, st2, m = adamw.update(grads, st, params, tcfg)
    # step 1: mhat = g, vhat = g^2 -> delta = g/(|g|+eps) = sign(g)
    want = params["w"] - 0.1 * jnp.sign(grads["w"])
    assert float(jnp.max(jnp.abs(new["w"] - want))) < 1e-4
    assert int(st2.step) == 1


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_weight_decay_mask():
    tcfg = TrainConfig(learning_rate=0.1, warmup_frac=0.0, weight_decay=1.0,
                       steps=10)
    params = {"mlp": {"wi_gate": jnp.ones((2, 2))},
              "norm1": {"w": jnp.ones((2,))}}
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    st = adamw.init(params)
    new, _, _ = adamw.update(grads, st, params, tcfg)
    # decayed matrix moves, norm scale does not
    assert float(jnp.abs(new["mlp"]["wi_gate"] - 1.0).max()) > 1e-3
    assert float(jnp.abs(new["norm1"]["w"] - 1.0).max()) < 1e-6


def test_lora_zero_b_is_identity():
    cfg = get_config("qwen2-0.5b").reduced(dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    lora = LoRA.init_lora(jax.random.PRNGKey(1), params, rank=4)
    merged = LoRA.merge(params, lora, alpha=8.0, rank=4)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0,
                                cfg.vocab_size)
    o1 = forward(params, tokens, cfg=cfg)
    o2 = forward(merged, tokens, cfg=cfg)
    assert float(jnp.max(jnp.abs(o1.logits - o2.logits))) < 1e-5


def test_lora_merge_equals_factored():
    cfg = get_config("qwen2-0.5b").reduced(dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    lora = LoRA.init_lora(jax.random.PRNGKey(1), params, rank=4)
    # random B
    lora = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.PRNGKey(3), x.shape) * 0.01,
        lora)
    merged = LoRA.merge(params, lora, alpha=8.0, rank=4)
    flat_m = jax.tree_util.tree_flatten_with_path(merged)[0]
    flat_p = dict((LoRA._path_str(p), l)
                  for p, l in jax.tree_util.tree_flatten_with_path(params)[0])
    changed = 0
    for path, leaf in flat_m:
        name = LoRA._path_str(path)
        base = flat_p[name]
        if name in lora:
            ab = jnp.einsum("...ir,...ro->...io", lora[name]["a"],
                            lora[name]["b"]) * 2.0
            assert float(jnp.max(jnp.abs(leaf - (base + ab)))) < 1e-5
            changed += 1
        else:
            assert (leaf == base).all()
    assert changed >= 6  # q,k,v,o + mlp targets exist


def test_lora_targets_attention_and_mlp():
    cfg = get_config("qwen2-0.5b").reduced(dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    lora = LoRA.init_lora(jax.random.PRNGKey(1), params, rank=4)
    names = set(n.split("/")[-1] for n in lora)
    assert {"wq", "wk", "wv", "wo", "wi_gate", "wi_up"} <= names


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced(dtype="float32")
    params = init_model(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    save(params, path)
    template = jax.tree_util.tree_map(jnp.zeros_like, params)
    back = restore(template, path)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32)))) == 0.0
