"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Sweeps shapes and dtypes per the brief; hypothesis drives the geometry of
the block-causal mask for the attention kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.block_attn import block_attention_ref, flash_block_attention
from repro.kernels.decode_attn import (
    decode_attention,
    decode_attention_ref,
    paged_decode_attention,
    paged_decode_attention_ref,
)
from repro.kernels.xent import fused_xent, xent_ref


def _gqa_ref(q, k, v, **kw):
    b, L, Kv, G, hd = q.shape
    qr = q.transpose(0, 2, 3, 1, 4).reshape(b, Kv * G, L, hd)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    ref = block_attention_ref(qr, kr, vr, **kw)
    return ref.reshape(b, Kv, G, L, hd).transpose(0, 3, 1, 2, 4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,mode,P,B,win,cap", [
    (128, "block_causal", 32, 16, None, None),
    (200, "block_causal", 40, 8, None, None),
    (256, "causal", 0, 1, None, None),
    (160, "bidirectional", 0, 1, None, None),
    (256, "block_causal", 64, 32, 64, 50.0),
    (192, "causal", 0, 1, 96, None),
])
def test_block_attn_vs_oracle(L, mode, P, B, win, cap, dtype):
    key = jax.random.PRNGKey(0)
    b, Kv, G, hd = 2, 2, 3, 64
    q = jax.random.normal(key, (b, L, Kv, G, hd)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, L, Kv, hd)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, L, Kv, hd)).astype(dtype)
    out = flash_block_attention(q, k, v, mode=mode, prompt_len=P,
                                block_size=B, window=win, scale=0.125,
                                softcap=cap)
    ref = _gqa_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32), mode=mode, prompt_len=P,
                   block_size=B, window=win, scale=0.125, softcap=cap)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out - ref))) < tol


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 3))
def test_block_attn_property_geometry(nb, bs_pow, p_quarter):
    B = 2 ** bs_pow
    P = p_quarter * 16
    L = P + nb * B * 4
    key = jax.random.PRNGKey(L)
    q = jax.random.normal(key, (1, L, 1, 2, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, L, 1, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, L, 1, 32))
    out = flash_block_attention(q, k, v, mode="block_causal", prompt_len=P,
                                block_size=B * 4, scale=0.2)
    ref = _gqa_ref(q, k, v, mode="block_causal", prompt_len=P,
                   block_size=B * 4, scale=0.2)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,Bq,Kv,G,clen,win", [
    (256, 32, 2, 4, 200, None),
    (256, 32, 2, 4, 0, None),
    (512, 16, 1, 8, 300, 128),
    (128, 8, 4, 1, 128, None),
    (384, 32, 2, 2, 37, None),
])
def test_decode_attn_vs_oracle(S, Bq, Kv, G, clen, win, dtype):
    b, hd = 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (b, Bq, Kv, G, hd)).astype(dtype)
    kc = jax.random.normal(ks[1], (b, S, Kv, hd)).astype(dtype)
    vc = jax.random.normal(ks[2], (b, S, Kv, hd)).astype(dtype)
    kb = jax.random.normal(ks[3], (b, Bq, Kv, hd)).astype(dtype)
    vb = jax.random.normal(ks[4], (b, Bq, Kv, hd)).astype(dtype)
    out = decode_attention(q, kc, vc, kb, vb, jnp.asarray(clen),
                           scale=0.125, window=win)
    ref = decode_attention_ref(
        q.astype(jnp.float32), kc.astype(jnp.float32),
        vc.astype(jnp.float32), kb.astype(jnp.float32),
        vb.astype(jnp.float32), clen, scale=0.125, window=win)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def _paged_inputs(key, b, Bq, Kv, G, hd, n_pages, page, n_t):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, Bq, Kv, G, hd))
    kp = jax.random.normal(ks[1], (n_pages, page, Kv, hd))
    vp = jax.random.normal(ks[2], (n_pages, page, Kv, hd))
    kb = jax.random.normal(ks[3], (b, Bq, Kv, hd))
    vb = jax.random.normal(ks[4], (b, Bq, Kv, hd))
    return q, kp, vp, kb, vb


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("page,n_t,lens,win,cap", [
    (16, 5, (40, 32), None, None),
    (16, 5, (0, 16), None, None),        # one empty lane, one 1-page lane
    (32, 4, (100, 37), 48, None),        # boundary page + sliding window
    (16, 3, (48, 48), None, 30.0),       # full tables + softcap
])
def test_paged_decode_attn_vs_oracle(page, n_t, lens, win, cap, dtype):
    """Paged kernel walks scattered, partially-allocated page tables with
    per-lane cache lengths and matches the gather-based oracle."""
    b, Bq, Kv, G, hd = 2, 8, 2, 4, 64
    n_pages = 12
    rng = jax.random.PRNGKey(page + n_t)
    q, kp, vp, kb, vb = _paged_inputs(rng, b, Bq, Kv, G, hd, n_pages, page,
                                      n_t)
    q, kp, vp = q.astype(dtype), kp.astype(dtype), vp.astype(dtype)
    kb, vb = kb.astype(dtype), vb.astype(dtype)
    # scattered, non-monotone page assignment; unallocated tail slots = -1
    perm = np.random.default_rng(0).permutation(n_pages)
    table = np.full((b, n_t), -1, np.int32)
    for lane, ln in enumerate(lens):
        for j in range(-(-ln // page)):
            table[lane, j] = perm[lane * n_t + j]
    table = jnp.asarray(table)
    clens = jnp.asarray(lens, jnp.int32)
    out = paged_decode_attention(q, kp, vp, kb, vb, table, clens,
                                 scale=0.125, window=win, softcap=cap)
    ref = paged_decode_attention_ref(
        q.astype(jnp.float32), kp.astype(jnp.float32),
        vp.astype(jnp.float32), kb.astype(jnp.float32),
        vb.astype(jnp.float32), table, clens, scale=0.125, window=win,
        softcap=cap)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out - ref))) < tol


def test_paged_decode_attn_matches_dense_on_contiguous_layout():
    """With an identity page table the paged kernel must reproduce the dense
    flash-decode kernel exactly (same tiles, same online-softmax order)."""
    b, Bq, Kv, G, hd = 2, 8, 2, 4, 64
    page, n_t = 16, 5
    q, kp, vp, kb, vb = _paged_inputs(jax.random.PRNGKey(7), b, Bq, Kv, G,
                                      hd, b * n_t, page, n_t)
    table = jnp.arange(b * n_t, dtype=jnp.int32).reshape(b, n_t)
    kc = kp.reshape(b, n_t * page, Kv, hd)
    vc = vp.reshape(b, n_t * page, Kv, hd)
    clen = 40
    dense = decode_attention(q, kc, vc, kb, vb, jnp.asarray(clen),
                             scale=0.125, block_k=page)
    paged = paged_decode_attention(q, kp, vp, kb, vb, table,
                                   jnp.full((b,), clen, jnp.int32),
                                   scale=0.125)
    assert np.array_equal(np.asarray(dense), np.asarray(paged))


@pytest.mark.parametrize("T,d,V", [(128, 64, 512), (200, 32, 1000),
                                   (64, 128, 593), (96, 48, 2048)])
def test_xent_vs_oracle(T, d, V):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (T, d)) * 0.5
    w = jax.random.normal(ks[1], (d, V)) * 0.1
    y = jax.random.randint(ks[2], (T,), 0, V)
    assert float(jnp.max(jnp.abs(fused_xent(h, w, y) - xent_ref(h, w, y)))) < 1e-4


def test_xent_grads_vs_oracle():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (64, 32)) * 0.5
    w = jax.random.normal(ks[1], (32, 640)) * 0.1
    y = jax.random.randint(ks[2], (64,), 0, 640)
    g1 = jax.grad(lambda h, w: fused_xent(h, w, y).mean(), (0, 1))(h, w)
    g2 = jax.grad(lambda h, w: xent_ref(h, w, y).mean(), (0, 1))(h, w)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_xent_bf16_inputs():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = (jax.random.normal(ks[0], (128, 64)) * 0.5).astype(jnp.bfloat16)
    w = (jax.random.normal(ks[1], (64, 512)) * 0.1).astype(jnp.bfloat16)
    y = jax.random.randint(ks[2], (128,), 0, 512)
    got = fused_xent(h, w, y)
    ref = xent_ref(h.astype(jnp.float32), w.astype(jnp.float32), y)
    assert float(jnp.max(jnp.abs(got - ref))) < 5e-2
