import os
import sys

# tests run on the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS); keep x64 off and make failures deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """XLA's CPU compiler can segfault inside ``backend_compile`` late in a
    long single-process run (hundreds of accumulated executables on jaxlib
    0.4.x) — the crash point moves between runs and every module passes in
    isolation. Dropping compiled-executable caches at module boundaries
    bounds the accumulation; per-module jit reuse is unaffected."""
    yield
    jax.clear_caches()
