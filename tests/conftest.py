import os
import sys

# tests run on the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS); keep x64 off and make failures deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
