"""Masked-diffusion process invariants (paper §3, Eq. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import diffusion as D

MASK = 99


@settings(max_examples=30, deadline=None)
@given(st.floats(0.0, 1.0), st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_mask_tokens_rate_and_preservation(t, L, seed):
    key = jax.random.PRNGKey(seed)
    tokens = jnp.arange(L) % 50
    masked, m = D.mask_tokens(key, tokens[None], t, MASK)
    masked, m = masked[0], m[0]
    # unmasked positions keep their token
    assert bool((jnp.where(m, MASK, tokens) == masked).all())
    # masked positions become MASK
    assert bool((masked[np.asarray(m)] == MASK).all())


def test_mask_tokens_respects_maskable():
    key = jax.random.PRNGKey(0)
    tokens = jnp.arange(32)[None]
    maskable = (jnp.arange(32) < 16)[None]
    masked, m = D.mask_tokens(key, tokens, 1.0, MASK, maskable)
    assert bool((masked[0, 16:] == tokens[0, 16:]).all())
    assert bool(m[0, :16].all())


@settings(max_examples=30, deadline=None)
@given(st.floats(0.05, 0.95), st.floats(0.0, 1.0))
def test_transition_probs_normalize(t, frac):
    s = t * frac * 0.99
    p_unmask = jax.nn.softmax(jnp.arange(5.0))
    tr = D.transition_probs(t, s, True, p_unmask)
    total = tr["keep"] + tr["still_masked"] + float(tr["unmask"].sum())
    assert abs(total - 1.0) < 1e-5
    tr2 = D.transition_probs(t, s, False, p_unmask)
    assert tr2["keep"] == 1.0 and tr2["still_masked"] == 0.0


def test_timestep_endpoints():
    assert D.timestep(0, 10) == 1.0 and D.timestep(10, 10) == 0.0


def test_confidence_masks_out_unmasked():
    logits = jnp.zeros((1, 4, 8)).at[0, 0, 3].set(5.0)
    tokens = jnp.asarray([[MASK % 8, 1, MASK % 8, 2]])
    cand, conf = D.confidence_and_candidates(logits, tokens, MASK % 8)
    assert bool(jnp.isinf(conf[0, 1])) and conf[0, 1] < 0
    assert bool(jnp.isfinite(conf[0, 0]))
    assert int(cand[0, 0]) == 3


def test_select_threshold_always_selects_one():
    conf = jnp.asarray([[0.1, 0.2, 0.15, -jnp.inf]])
    block = jnp.asarray([[True, True, True, True]])
    sel = D.select_threshold_in_block(conf, block, tau=0.9)
    assert int(sel.sum()) == 1 and bool(sel[0, 1])


def test_select_topk_empty_block():
    conf = jnp.full((1, 4), -jnp.inf)
    sel = D.select_topk_in_block(conf, jnp.ones((1, 4), bool), 1)
    assert int(sel.sum()) == 0
