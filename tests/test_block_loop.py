"""Unified block-decode engine: strategy ports are bit-identical to the
frozen seed samplers, and the per-lane cache primitives are exact.

The legacy implementations live in ``tests/_legacy_samplers.py`` (verbatim
from the seed PR, kept only as the equivalence reference).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _legacy_samplers as legacy
from repro.configs.registry import get_config
from repro.core import cache as C
from repro.core import masks
from repro.core.block_loop import (
    STRATEGIES,
    DecodeStrategy,
    SamplerSpec,
    lane_block_forward,
    run_block_loop,
)
from repro.core.sampler import SAMPLERS, vanilla_blockwise
from repro.models import forward, init_model

CFG = get_config("qwen2-0.5b").reduced(dtype="float32")
P, G, B = 8, 16, 4


@pytest.fixture(scope="module")
def setup():
    params = init_model(jax.random.PRNGKey(0), CFG)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, P), 2,
                                 CFG.vocab_size)
    return params, prompts


def _specs(**kw):
    return (SamplerSpec(prompt_len=P, gen_len=G, block_size=B, **kw),
            legacy.SamplerSpec(prompt_len=P, gen_len=G, block_size=B, **kw))


def _assert_results_equal(r_new, r_old, ctx):
    assert np.array_equal(r_new.tokens, r_old.tokens), ctx
    assert np.array_equal(r_new.steps, r_old.steps), ctx
    assert int(r_new.n_model_calls) == int(r_old.n_model_calls), ctx
    assert np.array_equal(r_new.gen_lengths, r_old.gen_lengths), ctx


LEGACY = {
    "vanilla": legacy.vanilla_blockwise,
    "fast_dllm": legacy.fast_dllm_parallel,
    "dual_cache": legacy.dual_cache,
    "interval_cache": legacy.interval_cache,
    "cdlm": legacy.cdlm,
    "ar": legacy.ar,
}


@pytest.mark.parametrize("name", sorted(SAMPLERS))
@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_strategy_port_equivalent_to_seed(setup, name, temperature):
    """Every SAMPLERS entry reproduces its seed implementation exactly:
    tokens, steps, n_model_calls, gen_lengths — including the RNG stream
    at nonzero temperature."""
    params, prompts = setup
    spec_new, spec_old = _specs(conf_threshold=0.5, temperature=temperature,
                                early_stop=True, cache_refresh_interval=3)
    key = jax.random.PRNGKey(42)
    r_new = SAMPLERS[name](params, prompts, cfg=CFG, spec=spec_new, key=key)
    r_old = LEGACY[name](params, prompts, cfg=CFG, spec=spec_old, key=key)
    _assert_results_equal(r_new, r_old, (name, temperature))


def test_trajectory_recording_equivalent_to_seed(setup):
    params, prompts = setup
    spec_new, spec_old = _specs()
    r_new, fat_new, hid_new = vanilla_blockwise(
        params, prompts, cfg=CFG, spec=spec_new, record_hidden=True)
    r_old, fat_old, hid_old = legacy.vanilla_blockwise(
        params, prompts, cfg=CFG, spec=spec_old, record_hidden=True)
    _assert_results_equal(r_new, r_old, "record_hidden")
    assert np.array_equal(fat_new, fat_old)
    assert np.array_equal(hid_new, hid_old)


def test_strategy_validation():
    with pytest.raises(ValueError):
        DecodeStrategy("x", masks.CAUSAL, "bogus-policy", "threshold")
    with pytest.raises(ValueError):
        DecodeStrategy("x", masks.CAUSAL, "none", "bogus-rule")


def test_record_hidden_requires_top1(setup):
    params, prompts = setup
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B)
    with pytest.raises(ValueError, match="top1"):
        run_block_loop(params, prompts, cfg=CFG, spec=spec,
                       strategy=STRATEGIES["cdlm"], record_hidden=True)


# ---------------------------------------------------------------------------
# Per-lane cache primitives
# ---------------------------------------------------------------------------
def test_cache_reset_touches_only_selected_lanes():
    cache = C.init_cache(CFG, 4, P + G, dtype="float32")
    filled = jax.tree_util.tree_map(
        lambda a: jnp.full(a.shape, 7.0, a.dtype), cache)
    rows = jnp.asarray([False, True, False, True])
    out = C.reset(filled, rows)
    for leaf in jax.tree_util.tree_leaves(out):
        assert float(jnp.abs(leaf[:, 1]).max()) == 0.0
        assert float(jnp.abs(leaf[:, 3]).max()) == 0.0
        assert float(jnp.abs(leaf[:, 0] - 7.0).max()) == 0.0
        assert float(jnp.abs(leaf[:, 2] - 7.0).max()) == 0.0
    # int lane indices are accepted too
    out2 = C.reset(filled, jnp.asarray([1, 3]))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(out2)):
        assert np.array_equal(a, b)


def test_commit_rows_matches_commit_per_lane(setup):
    """commit_rows at per-lane offsets == full commit restricted to those
    lanes, and untouched lanes keep their contents bit-for-bit."""
    params, _ = setup
    b = 3
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, P + B), 0,
                                CFG.vocab_size)
    out = forward(params, tokens[:, P:], cfg=CFG, mode=masks.BLOCK_CAUSAL,
                  prompt_len=P, block_size=B,
                  positions=P + jnp.arange(B))
    base = C.init_cache(CFG, b, P + G, dtype="float32")
    marked = jax.tree_util.tree_map(
        lambda a: jnp.full(a.shape, 3.0, a.dtype), base)
    rows = jnp.asarray([True, False, True])
    got = C.commit_rows(marked, out.emissions, P, rows)
    want_all = C.commit(marked, out.emissions, P)
    for g, w, m in zip(jax.tree_util.tree_leaves(got),
                       jax.tree_util.tree_leaves(want_all),
                       jax.tree_util.tree_leaves(marked)):
        assert np.array_equal(np.asarray(g[:, 0]), np.asarray(w[:, 0]))
        assert np.array_equal(np.asarray(g[:, 2]), np.asarray(w[:, 2]))
        assert np.array_equal(np.asarray(g[:, 1]), np.asarray(m[:, 1]))


def test_lane_block_forward_matches_shared_grid(setup):
    """Per-lane block decode at a shared offset == the batched block decode
    the cdlm sampler performs."""
    params, _ = setup
    b = 2
    tokens = jax.random.randint(jax.random.PRNGKey(5), (b, P + G), 2,
                                CFG.vocab_size)
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B)
    kv = C.init_cache(CFG, b, P + G, dtype="float32")
    out = forward(params, tokens[:, :P], cfg=CFG, mode=masks.BLOCK_CAUSAL,
                  prompt_len=P, block_size=B)
    kv = C.commit(kv, out.emissions, 0)
    ref = forward(params, tokens[:, P:P + B], cfg=CFG,
                  mode=masks.BLOCK_CAUSAL, prompt_len=P, block_size=B,
                  positions=P + jnp.arange(B), cache=kv, cache_len=P)
    starts = jnp.full((b,), P, jnp.int32)
    logits, emissions = lane_block_forward(params, tokens, starts, kv,
                                           cfg=CFG, spec=spec)
    assert float(jnp.max(jnp.abs(logits - ref.logits))) < 5e-5
    for a, r in zip(jax.tree_util.tree_leaves(emissions),
                    jax.tree_util.tree_leaves(ref.emissions)):
        assert float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - r.astype(jnp.float32)))) < 5e-5


def test_lane_block_forward_mixed_offsets(setup):
    """Lanes decoding different blocks in one batch produce the same logits
    as each lane decoded at its offset in isolation."""
    params, _ = setup
    tokens = jax.random.randint(jax.random.PRNGKey(6), (2, P + G), 2,
                                CFG.vocab_size)
    spec = SamplerSpec(prompt_len=P, gen_len=G, block_size=B)
    kv = C.init_cache(CFG, 2, P + G, dtype="float32")
    out = forward(params, tokens[:, :P + B], cfg=CFG, mode=masks.BLOCK_CAUSAL,
                  prompt_len=P, block_size=B)
    kv = C.commit(kv, out.emissions, 0)
    # lane 0 decodes block 0, lane 1 decodes block 1
    starts = jnp.asarray([P, P + B], jnp.int32)
    logits, _ = lane_block_forward(params, tokens, starts, kv, cfg=CFG,
                                   spec=spec)
    for lane, s in ((0, P), (1, P + B)):
        solo = lane_block_forward(
            params, tokens[lane:lane + 1],
            jnp.asarray([s], jnp.int32),
            jax.tree_util.tree_map(lambda a: a[:, lane:lane + 1], kv),
            cfg=CFG, spec=spec)[0]
        assert float(jnp.max(jnp.abs(logits[lane] - solo[0]))) < 5e-5
