"""Deliverable (f): per-architecture smoke tests.

Each assigned arch instantiates its REDUCED variant (2 periods, d_model<=256,
<=4 experts) and runs one forward + one train step + one decode step on CPU,
asserting output shapes and absence of NaNs.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import CDLMConfig, TrainConfig
from repro.configs.registry import ARCHITECTURES, get_config
from repro.core import masks
from repro.core import cache as C
from repro.models import forward, init_model
from repro.optim import adamw
from repro.training.steps import ar_loss, cdlm_loss

ARCHS = sorted(ARCHITECTURES)


def _reduced(arch):
    return get_config(arch).reduced(dtype="float32")


def _extras(cfg, b, key):
    e = {}
    if cfg.is_encoder_decoder:
        e["encoder_embeds"] = 0.1 * jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model))
    if cfg.n_prefix_embeds:
        e["prefix_embeds"] = 0.1 * jax.random.normal(
            key, (b, cfg.n_prefix_embeds, cfg.d_model))
    return e


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = _reduced(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    b, L = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, L), 0,
                                cfg.vocab_size)
    extras = _extras(cfg, b, jax.random.PRNGKey(2))
    mode = masks.CAUSAL if cfg.is_attention_free else masks.BLOCK_CAUSAL
    out = forward(params, tokens, cfg=cfg, mode=mode, prompt_len=8,
                  block_size=4, **extras)
    off = cfg.n_prefix_embeds
    assert out.logits.shape == (b, off + L, cfg.vocab_size)
    assert out.hidden.shape == (b, off + L, cfg.d_model)
    assert bool(jnp.isfinite(out.logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = _reduced(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(steps=2, batch_size=2, remat=False,
                       learning_rate=1e-3)
    opt = adamw.init(params)
    b, P, G = 2, 8, 8
    key = jax.random.PRNGKey(1)
    extras = _extras(cfg, b, key)

    if cfg.family == "ssm":
        batch = {"prompt": jax.random.randint(key, (b, P), 2, cfg.vocab_size),
                 "answer": jax.random.randint(key, (b, G), 2, cfg.vocab_size),
                 "maskable": jnp.ones((b, G), bool)}
        (loss, _), grads = jax.value_and_grad(ar_loss, has_aux=True)(
            params, batch, key, cfg=cfg)
    else:
        cdlm = CDLMConfig(block_size=4, gen_length=G, prompt_length=P)
        tok = lambda *s: jax.random.randint(key, s, 2, cfg.vocab_size)
        batch = {
            "y": tok(b, P + G), "y_star": tok(b, P + G),
            "u_mask": jnp.zeros((b, P + G), bool).at[:, P + 1].set(True),
            "s_mask": jnp.zeros((b, P + G), bool).at[:, P + 5].set(True),
            "teacher_hidden": 0.1 * jax.random.normal(key, (b, G, cfg.d_model)),
            "gt": tok(b, G), "prompt": tok(b, P),
        }
        (loss, _), grads = jax.value_and_grad(cdlm_loss, has_aux=True)(
            params, None, batch, key, cfg=cfg, cdlm=cdlm,
            teacher_head=params["embed"], use_lora=False, extras=extras)

    assert bool(jnp.isfinite(loss))
    new_params, _, m = adamw.update(grads, opt, params, tcfg)
    assert bool(jnp.isfinite(m["grad_norm"]))
    # parameters actually moved
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(new_params),
                                jax.tree_util.tree_leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_decode_step(arch):
    cfg = _reduced(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    b, P, B = 2, 8, 4
    S = P + 2 * B
    key = jax.random.PRNGKey(1)
    extras = _extras(cfg, b, key)
    mode = masks.CAUSAL if cfg.is_attention_free else masks.BLOCK_CAUSAL
    Bq = 1 if cfg.family == "ssm" else B

    kv = C.init_cache(cfg, b, 0 if cfg.is_attention_free else S,
                      dtype="float32")
    out = forward(params, jax.random.randint(key, (b, P), 0, cfg.vocab_size),
                  cfg=cfg, mode=mode, prompt_len=P + cfg.n_prefix_embeds,
                  block_size=B, **extras)
    kv = C.commit(kv, out.emissions, 0)
    blk = forward(params, jnp.full((b, Bq), cfg.mask_token_id, jnp.int32),
                  cfg=cfg, mode=mode, prompt_len=P + cfg.n_prefix_embeds,
                  block_size=Bq,
                  positions=P + cfg.n_prefix_embeds + jnp.arange(Bq),
                  cache=kv, cache_len=P + cfg.n_prefix_embeds)
    assert blk.logits.shape == (b, Bq, cfg.vocab_size)
    assert bool(jnp.isfinite(blk.logits).all())


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "internvl2-1b": (24, 896, 14, 2, 4864, 151_655),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202_048),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151_936),
        "rwkv6-1.6b": (24, 2048, None, None, 7168, 65_536),
        "gemma-7b": (28, 3072, 16, 16, 24_576, 256_000),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14_336, 65_536),
        "gemma2-27b": (46, 4608, 32, 16, 36_864, 256_000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163_840),
        "qwen1.5-110b": (80, 8192, 64, 8, 49_152, 152_064),
        "whisper-base": (6, 512, 8, 8, 2048, 51_865),
    }
    for arch, (nl, dm, nh, nkv, dff, vs) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == nl, arch
        assert cfg.d_model == dm, arch
        if nh is not None:
            assert cfg.n_heads == nh and cfg.n_kv_heads == nkv, arch
        assert cfg.d_ff == dff and cfg.vocab_size == vs, arch
    # MoE specifics
    k = get_config("kimi-k2-1t-a32b")
    assert k.n_experts == 384 and k.experts_per_token == 8
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.n_experts == 128 and l4.experts_per_token == 1
    j = get_config("jamba-v0.1-52b")
    assert j.n_experts == 16 and j.experts_per_token == 2
    # jamba 1:7 attention:mamba interleave
    from repro.configs.base import ATTN, MAMBA
    mixers = [m for m, _ in j.layer_period]
    assert mixers.count(ATTN) == 1 and mixers.count(MAMBA) == 7
    # gemma2 alternation + softcaps
    g2 = get_config("gemma2-27b")
    assert g2.sliding_window == 4096
    assert g2.attn_logit_softcap == 50.0 and g2.final_logit_softcap == 30.0


def test_param_counts_in_expected_range():
    """Analytic N within ~35% of the nameplate (sanity on config wiring)."""
    expect = {
        "qwen2-0.5b": 0.5e9, "gemma-7b": 8.5e9, "gemma2-27b": 27e9,
        "qwen1.5-110b": 110e9, "kimi-k2-1t-a32b": 1.0e12,
        "llama4-maverick-400b-a17b": 400e9, "jamba-v0.1-52b": 52e9,
        "rwkv6-1.6b": 1.6e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.55 * n < got < 1.45 * n, (arch, got, n)
    # active params of the trillion-scale MoE ~32B
    a = get_config("kimi-k2-1t-a32b").active_param_count()
    assert 15e9 < a < 50e9, a
